"""Elastic fleet runtime tests (DESIGN.md §9, core/fleet.py).

Fast subset: the lifecycle state machine, the FleetExecutor, the
DrainTrigger, and the trace-EMA decode-length predictor — all pure
python. The multi-TE lifecycle tests (drain-under-load parity,
release-then-refork window reuse, M:N groups, executor parity, the
fork-while-draining regression) spin several live engines and live in
the slow lane (markers: ``slow`` + ``fleet``).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fleet import (FleetExecutor, LifecycleError, TEState,
                              advance)
from repro.core.predictor import TraceEMAPredictor
from repro.core.scaling import (DrainTrigger, DRAMPageCache, FastScaler,
                                LoadSpreadTrigger)
from repro.core.scheduling import TEHandle
from repro.core.serving_plane import ServingJobEngine, TopologySpec
from repro.engine import EngineConfig, FlowServe, Request, SamplingParams
from repro.models import get_model

SP = SamplingParams(temperature=0.0, max_new_tokens=10, stop_on_eos=False)
LENS, RATIOS = [16, 64], [0.25, 1.0]
PD_HEAT = np.ones((2, 2))
COLO_HEAT = -np.ones((2, 2))


def _ecfg(**kw):
    base = dict(n_pages=64, page_size=8, max_batch_tokens=32,
                chunk_size=8, max_decode_batch=4)
    base.update(kw)
    return EngineConfig(**base)


def _plane(bundle, params, topo, heat=COLO_HEAT, **kw):
    return ServingJobEngine(bundle, params, topo, heatmap=heat,
                            prefill_lens=LENS, decode_ratios=RATIOS,
                            ecfg=_ecfg(), **kw)


def _prompts(n, length=14, seed0=0):
    return [[1] + [int(x) for x in
                   np.random.RandomState(seed0 + i).randint(3, 200, length)]
            for i in range(n)]


@pytest.fixture(scope="module")
def qwen():
    bundle = get_model("qwen3-8b", smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    return bundle, params


def _reference_tokens(bundle, params, prompts, sp=SP):
    ref = FlowServe(bundle, params, _ecfg(), name="lref")
    ids = [ref.add_request(Request(prompt_tokens=p, sampling=sp))
           for p in prompts]
    comps = {c.req_id: c.tokens for c in ref.run_to_completion()}
    return [comps[i] for i in ids]


# ---------------------------------------------------------------------------
# Fast: lifecycle state machine
# ---------------------------------------------------------------------------


def test_lifecycle_walk_and_illegal_transitions():
    # the canonical walk is legal end to end
    s = TEState.PROVISIONING
    for nxt in (TEState.WARMING, TEState.SERVING, TEState.DRAINING,
                TEState.SERVING, TEState.DRAINING, TEState.RELEASED):
        s = advance(s, nxt)
    assert s is TEState.RELEASED
    # RELEASED is terminal; skipping states raises
    for cur, bad in [(TEState.RELEASED, TEState.SERVING),
                     (TEState.RELEASED, TEState.PROVISIONING),
                     (TEState.PROVISIONING, TEState.SERVING),
                     (TEState.WARMING, TEState.DRAINING),
                     (TEState.SERVING, TEState.RELEASED),
                     (TEState.SERVING, TEState.WARMING)]:
        with pytest.raises(LifecycleError):
            advance(cur, bad)


def test_tehandle_transition_and_admitting():
    h = TEHandle("t", "colocated", state=TEState.PROVISIONING)
    assert not h.admitting
    h.transition(TEState.WARMING)
    h.transition(TEState.SERVING)
    assert h.admitting
    h.transition(TEState.DRAINING)
    assert not h.admitting
    with pytest.raises(LifecycleError):
        h.transition(TEState.WARMING)
    h.transition(TEState.RELEASED)
    assert h.state is TEState.RELEASED


# ---------------------------------------------------------------------------
# Fast: FleetExecutor
# ---------------------------------------------------------------------------


def test_fleet_executor_submit_collect_and_pinning():
    ex = FleetExecutor(2)
    log = {}

    def work(unit, i):
        # record which thread serves each unit: pinning keeps it stable
        import threading
        log.setdefault(unit, set()).add(threading.current_thread().name)
        return (unit, i)

    for rep in range(3):
        for unit in ("a", "b", "c"):      # 3 units share 2 workers
            ex.submit(unit, (lambda u=unit, r=rep: work(u, r)))
        done, failed = ex.collect(3)
        assert failed == []
        got = sorted(done)
        assert got == [("a", ("a", rep)), ("b", ("b", rep)),
                       ("c", ("c", rep))]
    assert all(len(threads) == 1 for threads in log.values())
    ex.close()


def test_fleet_executor_quarantines_failures_and_returns_survivors():
    """Regression (§11): one failing unit no longer aborts the other
    units' step — collect() never raises; survivors' results surface and
    the failure is reported alongside, for the caller to quarantine."""
    ex = FleetExecutor(2)
    ran = []

    def boom():
        raise RuntimeError("unit exploded")

    ex.submit("ok", lambda: ran.append(1) or "fine")
    ex.submit("bad", boom)
    done, failed = ex.collect(2)
    assert done == [("ok", "fine")]       # the survivor's result returned
    assert ran == [1]                     # and its work genuinely ran
    assert len(failed) == 1
    tag, exc = failed[0]
    assert tag == "bad"
    assert isinstance(exc, RuntimeError) and "unit exploded" in str(exc)
    ex.close()


# ---------------------------------------------------------------------------
# Fast: DrainTrigger + mutual-exclusion semantics
# ---------------------------------------------------------------------------


def test_drain_trigger_semantics():
    trig = DrainTrigger(low_watermark=2.0, patience=3, min_serving=1)
    # loaded fleet never drains
    assert not trig.observe([10.0, 8.0])
    # sustained low watermark fires exactly at patience
    assert not trig.observe([0.5, 0.1])
    assert not trig.observe([0.5, 0.1])
    assert trig.observe([0.5, 0.1])
    # one-shot: stays disarmed while the drain is in flight
    for _ in range(10):
        assert not trig.observe([0.1, 0.0])
    # the completed drain re-arms it (release calls rearm)
    trig.rearm()
    for _ in range(2):
        assert not trig.observe([0.1])  # n_serving defaults to len(loads)=1
    # at min_serving the trigger never fires regardless of load
    assert trig.fires == 1
    assert not trig.observe([0.0, 0.0], n_serving=1)
    # above min_serving it counts down again
    assert not trig.observe([0.1, 0.0])
    assert not trig.observe([0.1, 0.0])
    assert trig.observe([0.1, 0.0])
    assert trig.fires == 2


# ---------------------------------------------------------------------------
# Fast: trace-EMA decode-length predictor (PR-4 follow-up)
# ---------------------------------------------------------------------------


def test_trace_ema_predictor_converges_per_mix():
    pred = TraceEMAPredictor(alpha=0.3, default_guess=64)
    rng = np.random.RandomState(0)
    short = [list(rng.randint(3, 200, 8)) for _ in range(40)]
    long = [list(rng.randint(3, 200, 300)) for _ in range(40)]
    # before any trace: the default guess
    assert pred.predict_tokens(short[0]) == 64
    for s, l in zip(short, long):
        # shortP/longD vs longP/shortD — the serving mixes' signature
        pred.observe(s, 24 + int(rng.randn() * 2))
        pred.observe(l, 6 + int(rng.randn() * 1))
    # the two mixes separate (per-bin EMA) and the estimates converge
    assert abs(pred.predict_tokens(short[0]) - 24) <= 3
    assert abs(pred.predict_tokens(long[0]) - 6) <= 2
    assert pred.n_observations() == 80
    # an untrained mix falls back to the nearest trained one, not default
    assert pred.predict_tokens(list(rng.randint(3, 200, 16))) \
        == pred.predict_tokens(short[0])


def test_trace_ema_predictor_converges_load_estimates():
    """The plane-level effect: committed load (prompt + predicted_decode)
    converges to the actually-consumed tokens as traces accumulate."""
    pred = TraceEMAPredictor(alpha=0.3, default_guess=128)
    rng = np.random.RandomState(1)
    actual_decode = 20
    drift = []
    for _ in range(50):
        prompt = list(rng.randint(3, 200, 12))
        predicted = pred.predict_tokens(prompt)
        drift.append(abs(predicted - actual_decode))
        pred.observe(prompt, actual_decode)
    assert drift[0] == abs(128 - actual_decode)    # cold start: way off
    assert max(drift[-10:]) <= 1                   # converged estimates


def test_topology_parse_mn_groups():
    t = TopologySpec.parse("pd=1p2d,colo=1")
    assert t.groups() == [(1, 2)] and t.colo == 1 and t.n_engines() == 4
    t2 = TopologySpec.parse("pd=2p3d,colo=0")
    assert t2.groups() == [(2, 3)] and t2.n_engines() == 5
    # pd=N keeps meaning N 1P:1D pairs
    assert TopologySpec.parse("pd=2,colo=1").groups() == [(1, 1), (1, 1)]
    with pytest.raises(ValueError):
        TopologySpec.parse("pd=0p2d")


# ---------------------------------------------------------------------------
# Multi-TE lifecycle (slow + fleet): drain parity, window reuse, M:N,
# executor parity, fork-while-draining regression
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.fleet
def test_drain_under_load_parity(qwen):
    """Every in-flight request on a draining TE completes or migrates out
    (§7 sharded path) with greedy token parity, then the TE releases."""
    bundle, params = qwen
    sp = SamplingParams(temperature=0.0, max_new_tokens=24,
                        stop_on_eos=False)
    prompts = _prompts(4)
    je = _plane(bundle, params, TopologySpec(pd=0, colo=2),
                policy="round_robin")
    rids = [je.submit(p, sampling=sp) for p in prompts]
    for _ in range(2):
        je.step()
    victim = je.handles[1]
    assert victim.engine.migratable_running(), \
        "drain must start with decodes in flight"
    je.drain(victim.te_id)
    assert not victim.admitting
    je.run_to_completion()
    comps = {c.req_id: c.tokens for c in je.completions}
    assert len(comps) == 4
    ref = _reference_tokens(bundle, params, prompts, sp)
    assert [comps[r] for r in rids] == ref
    # mid-decode KV really crossed DistFlow (not just local completion)
    assert victim.engine.distflow.bytes_moved() > 0
    # the victim fully drained and RELEASED; the survivor served its seqs
    assert victim.state is TEState.RELEASED
    assert [h.te_id for h in je.handles] == ["te-colo0"]
    assert je.handles[0].engine.decode_steps > 0
    kinds = [e["kind"] for e in je.scale_events]
    assert kinds == ["drain", "release"]


@pytest.mark.slow
@pytest.mark.fleet
def test_release_then_refork_reuses_device_window(qwen):
    """Scale-in frees the TE's device window; the next fork takes it from
    the free list instead of growing the fleet's device footprint."""
    bundle, params = qwen
    je = _plane(bundle, params, TopologySpec(pd=0, colo=2),
                policy="round_robin",
                scaler=FastScaler(DRAMPageCache()))
    assert je._window_of == {"te-colo0": 0, "te-colo1": 1}
    je.submit(_prompts(1)[0], sampling=SP)
    je.run_to_completion()
    je.drain("te-colo1")
    je.run_to_completion()
    assert je._free_windows == [1]
    je._scale_out()                       # refork (trigger-independent)
    forked = je.engines[-1]
    assert forked.name == "te-scale0"
    assert forked.ecfg.device_offset == 1          # the freed window
    assert je._window_of == {"te-colo0": 0, "te-scale0": 1}
    assert je._free_windows == []
    # the reforked TE walked the lifecycle and serves traffic
    assert je.scheduler.tes["te-scale0"].state is TEState.SERVING
    rid = je.submit(_prompts(1, seed0=7)[0], sampling=SP,
                    predicted_decode=8)
    comps = {c.req_id: c.tokens for c in je.run_to_completion()}
    assert rid in comps


@pytest.mark.slow
@pytest.mark.fleet
def test_mn_group_spreads_handoffs_with_parity(qwen):
    """A pd=1p2d group: one prefill TE feeds BOTH decode members (least-
    loaded pick per handoff) and tokens match the single-TE reference."""
    bundle, params = qwen
    prompts = _prompts(4)
    je = _plane(bundle, params, TopologySpec.parse("pd=1p2d,colo=0"),
                heat=PD_HEAT)
    rids = [je.submit(p, sampling=SP) for p in prompts]
    comps = {c.req_id: c.tokens for c in je.run_to_completion()}
    assert len(comps) == 4
    assert [comps[r] for r in rids] == _reference_tokens(bundle, params,
                                                         prompts)
    group = je.handles[0]
    des = group.decode_members()
    assert len(des) == 2
    # both decode members actually decoded (handoffs spread by load)
    assert all(d.decode_steps > 0 for d in des)
    assert group.engine.decode_steps == 0          # prefill member didn't


@pytest.mark.slow
@pytest.mark.fleet
def test_fleet_threads_token_parity_and_equal_decisions(qwen):
    """The executor layer may change wall-clock only: the same batch
    through serial and threaded planes yields identical placement
    decisions and identical greedy tokens."""
    bundle, params = qwen
    prompts = _prompts(6)
    runs = {}
    for label, ft in (("serial", 0), ("threads", 2)):
        je = _plane(bundle, params, TopologySpec(pd=1, colo=1),
                    heat=PD_HEAT, fleet_threads=ft)
        rids = [je.submit(p, sampling=SP) for p in prompts]
        comps = {c.req_id: c.tokens for c in je.run_to_completion()}
        runs[label] = ([comps[r] for r in rids],
                       dict(je.scheduler.decisions))
        je.close()
    assert runs["serial"][0] == runs["threads"][0]
    assert runs["serial"][1] == runs["threads"][1]
    assert runs["serial"][0] == _reference_tokens(bundle, params, prompts)


@pytest.mark.slow
@pytest.mark.fleet
def test_no_fork_while_draining_regression(qwen):
    """LoadSpreadTrigger and the drain path are mutually exclusive per TE:
    a spread breach during an active drain (the draining TE's load
    collapsing looks exactly like skew) must NOT fork, and the trigger
    must not even advance its breach counter until the drain completes."""
    bundle, params = qwen
    trig = LoadSpreadTrigger(threshold=0.2, patience=1, min_load=0.5,
                             max_fires=5)
    je = _plane(bundle, params, TopologySpec(pd=0, colo=2),
                policy="round_robin", scaler=FastScaler(DRAMPageCache()),
                trigger=trig)
    sp = SamplingParams(temperature=0.0, max_new_tokens=24,
                        stop_on_eos=False)
    # IDENTICAL prompts round-robined: both TEs carry the same load, so the
    # patience=1 hair trigger cannot fire before the drain begins
    prompt = _prompts(1)[0]
    rids = [je.submit(list(prompt), sampling=sp) for _ in range(4)]
    je.step()
    je.drain("te-colo1")
    b0 = trig.breach_steps
    # draining migrates the victim's seqs onto the survivor: the spread
    # (loaded survivor vs emptying victim) now BREACHES every step — the
    # mutual exclusion must keep the trigger unfed until RELEASED
    for _ in range(300):
        if not any(h.state is TEState.DRAINING for h in je.handles):
            break
        je.step()
        assert not any(e["kind"] == "fork" for e in je.scale_events), \
            "forked while a TE was draining"
        assert trig.breach_steps == b0, "trigger fed during a drain"
    assert not any(h.state is TEState.DRAINING for h in je.handles), \
        "drain failed to release within 300 steps"
    je.run_to_completion()
    assert {c.req_id for c in je.completions} == set(rids)
    # after the drain completes the trigger is live again (not wedged)
    assert trig.armed and trig.fires == 0
