"""Per-kernel validation (deliverable c): sweep shapes/dtypes, interpret-
mode Pallas vs the pure-jnp oracle in ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-4


@pytest.mark.parametrize("b,h,hkv,hd,page,npages", [
    (1, 4, 4, 16, 8, 3),      # MHA
    (2, 8, 4, 32, 16, 5),     # GQA
    (3, 8, 1, 64, 16, 4),     # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("softcap,window", [(None, None), (30.0, None), (None, 20)])
def test_paged_attention(b, h, hkv, hd, page, npages, dtype, softcap, window):
    ks = jax.random.split(KEY, 4)
    pool = npages * b + 2
    q = jax.random.normal(ks[0], (b, h, hd), dtype)
    kp = jax.random.normal(ks[1], (pool, page, hkv, hd), dtype)
    vp = jax.random.normal(ks[2], (pool, page, hkv, hd), dtype)
    bt = jax.random.permutation(ks[3], pool)[: b * npages].reshape(b, npages).astype(jnp.int32)
    lengths = jnp.asarray(np.random.RandomState(0).randint(1, npages * page, b), jnp.int32)
    o_p = ops.paged_attention(q, kp, vp, bt, lengths, softcap=softcap,
                              window=window, impl="pallas")
    o_r = ops.paged_attention(q, kp, vp, bt, lengths, softcap=softcap,
                              window=window, impl="ref")
    np.testing.assert_allclose(np.asarray(o_p, np.float32),
                               np.asarray(o_r, np.float32), atol=_tol(dtype))


@pytest.mark.parametrize("b,s,h,hkv,hd,bq,bk", [
    (1, 128, 4, 4, 16, 32, 32),
    (2, 256, 8, 2, 32, 64, 128),
    (1, 64, 2, 1, 64, 64, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("softcap,window", [(None, None), (50.0, 48)])
def test_flash_prefill(b, s, h, hkv, hd, bq, bk, dtype, softcap, window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, hd), dtype)
    o_p = ops.flash_prefill(q, k, v, softcap=softcap, window=window,
                            block_q=bq, block_k=bk, impl="pallas")
    o_r = ops.flash_prefill(q, k, v, softcap=softcap, window=window, impl="ref")
    np.testing.assert_allclose(np.asarray(o_p, np.float32),
                               np.asarray(o_r, np.float32), atol=_tol(dtype))


@pytest.mark.parametrize("b,t,h,hd,chunk", [(1, 64, 2, 16, 16),
                                            (2, 128, 3, 32, 32),
                                            (1, 96, 1, 64, 48)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6(b, t, h, hd, chunk, dtype):
    ks = jax.random.split(KEY, 5)
    r = (jax.random.normal(ks[0], (b, t, h, hd)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (b, t, h, hd)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (b, t, h, hd)) * 0.5).astype(dtype)
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, t, h, hd)) * 0.5 - 1.0)).astype(dtype)
    u = (jax.random.normal(ks[4], (h, hd)) * 0.3).astype(jnp.float32)
    y_p = ops.wkv6(r, k, v, w, u, chunk=chunk, impl="pallas")
    y_r = ops.wkv6(r, k, v, w, u, impl="ref")
    np.testing.assert_allclose(np.asarray(y_p, np.float32),
                               np.asarray(y_r, np.float32),
                               atol=3e-2 if dtype == jnp.bfloat16 else 2e-3)


@pytest.mark.parametrize("b,t,w,chunk,bw", [(1, 128, 128, 32, 128),
                                            (2, 256, 256, 64, 128),
                                            (1, 64, 384, 64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru(b, t, w, chunk, bw, dtype):
    ks = jax.random.split(KEY, 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (b, t, w))).astype(dtype)
    bb = (jax.random.normal(ks[1], (b, t, w)) * 0.2).astype(dtype)
    h0 = (jax.random.normal(ks[2], (b, w)) * 0.5).astype(dtype)
    y_p = ops.rglru(a, bb, h0, chunk=chunk, block_w=bw, impl="pallas")
    y_r = ops.rglru(a, bb, h0, impl="ref")
    np.testing.assert_allclose(np.asarray(y_p, np.float32),
                               np.asarray(y_r, np.float32),
                               atol=5e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_wkv_chunked_equals_sequential_models():
    """models.rwkv6 chunked == sequential (the train/prefill formulation)."""
    from repro.models.rwkv6 import wkv_chunked, wkv_sequential
    ks = jax.random.split(KEY, 5)
    b, t, h, hd = 2, 80, 2, 16
    r, k, v = (jax.random.normal(ks[i], (b, t, h, hd)) * 0.5 for i in range(3))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, t, h, hd)) * 0.5 - 1.0))
    u = jax.random.normal(ks[4], (h, hd)) * 0.3
    y1, s1 = wkv_sequential(r, k, v, w, u)
    y2, s2 = wkv_chunked(r, k, v, w, u, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4)
