"""Hypothesis property tests on system invariants (deliverable c)."""
import string

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.engine.kv_cache import OutOfPagesError, pages_needed
from repro.engine.radix_tree import RadixTree
from repro.engine.tokenizer import ByteTokenizer

token_seqs = st.lists(st.integers(3, 40), min_size=1, max_size=24)


# ---------------------------------------------------------------------------
# Radix tree vs naive longest-common-prefix model
# ---------------------------------------------------------------------------


@settings(max_examples=200, deadline=None)
@given(st.lists(token_seqs, min_size=1, max_size=12), token_seqs)
def test_radix_matches_naive_lcp(inserted, query):
    tree = RadixTree()
    for i, seq in enumerate(inserted):
        tree.insert(seq, payload=("entry", i))
    matched, path = tree.match_prefix(query)
    naive = max((len(_lcp(seq, query)) for seq in inserted), default=0)
    assert matched == naive
    if matched > 0:
        # the reported subtree must contain an entry sharing `matched` tokens
        payload = None
        for node in reversed(path):
            payload = node.payload or tree.any_payload(node)
            if payload is not None:
                break
        assert payload is not None
        _, idx = payload
        assert tuple(inserted[idx][:matched]) == tuple(query[:matched])


def _lcp(a, b):
    out = []
    for x, y in zip(a, b):
        if x != y:
            break
        out.append(x)
    return out


@settings(max_examples=100, deadline=None)
@given(st.lists(token_seqs, min_size=1, max_size=10))
def test_radix_insert_then_exact_match(seqs):
    tree = RadixTree()
    for i, seq in enumerate(seqs):
        tree.insert(seq, payload=i)
    for seq in seqs:
        matched, _ = tree.match_prefix(seq)
        assert matched == len(seq)


# ---------------------------------------------------------------------------
# Page allocator invariants
# ---------------------------------------------------------------------------


class _AllocModel:
    """Reference model: set-based allocator."""

    def __init__(self, n):
        self.free = set(range(n))
        self.held = {}


@settings(max_examples=100, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "free"]),
                          st.integers(1, 5)), min_size=1, max_size=40))
def test_pool_allocator_invariants(ops_list):
    from repro.configs import get_config, smoke_config
    from repro.engine.kv_cache import PagedKVPool
    cfg = smoke_config(get_config("qwen3-8b"))
    pool = PagedKVPool(cfg, n_pages=16, page_size=4)
    held = []
    for op, n in ops_list:
        if op == "alloc":
            try:
                pages = pool.alloc(n)
            except OutOfPagesError:
                assert pool.free_page_count() < n
                continue
            assert len(set(pages)) == n            # no duplicates
            for run in held:
                assert not (set(run) & set(pages))  # no double-allocation
            held.append(pages)
        elif held:
            run = held.pop(np.random.RandomState(n).randint(len(held)))
            pool.release(run)
    total_held = sum(len(r) for r in held)
    assert pool.free_page_count() + total_held + len(pool.reclaimable()) \
        + sum(1 for p, r in pool._refs.items() if r.ref_count > 0 and p not in
              [x for run in held for x in run]) >= 16 - total_held
    assert pool.free_page_count() == 16 - total_held


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 200), st.integers(1, 32))
def test_pages_needed(tokens, page):
    n = pages_needed(tokens, page)
    assert n * page >= tokens
    assert (n - 1) * page < tokens


# ---------------------------------------------------------------------------
# Tokenizer roundtrip
# ---------------------------------------------------------------------------


@settings(max_examples=150, deadline=None)
@given(st.text(alphabet=string.printable, max_size=200))
def test_tokenizer_roundtrip(text):
    tok = ByteTokenizer()
    assert tok.decode(tok.encode(text)) == text


# ---------------------------------------------------------------------------
# Data pipeline invariants
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(8, 32))
def test_packing_shapes_and_sharding(batch, seq):
    from repro.data import DataConfig, PackedDataset
    full = PackedDataset(DataConfig(seq_len=seq, batch_size=batch, n_docs=64))
    tokens, targets, mask = next(full.batches())
    assert tokens.shape == (batch, seq) == targets.shape == mask.shape
    # next-token alignment
    assert (tokens[:, 1:] == targets[:, :-1]).all()
    # DP sharding partitions the docs: shards are disjoint subsets
    s0 = PackedDataset(DataConfig(seq_len=seq, batch_size=1, n_docs=64,
                                  dp_rank=0, dp_size=2))
    s1 = PackedDataset(DataConfig(seq_len=seq, batch_size=1, n_docs=64,
                                  dp_rank=1, dp_size=2))
    assert len(s0.windows) + len(s1.windows) <= len(full.windows) + 2


# ---------------------------------------------------------------------------
# Scheduler: chunked prefill never exceeds the token budget
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(2, 60), min_size=1, max_size=6),
       st.integers(8, 64), st.integers(4, 16))
def test_chunked_prefill_budget(prompt_lens, budget, chunk):
    from repro.engine.model_runner import SequenceState
    from repro.engine.scheduler import Scheduler, SchedulerConfig
    sched = Scheduler(SchedulerConfig(max_batch_tokens=budget,
                                      chunk_size=chunk), rtc=None, paged=True)
    for i, n in enumerate(prompt_lens):
        sched.admit(SequenceState(f"s{i}", list(range(n)), n))
    sched.resolve_prefix()
    plan = sched.prepare_next()
    total = len(plan.decode) + sum(len(c) for _, _, c in plan.prefill)
    assert total <= budget
    for seq, start, c in plan.prefill:
        assert len(c) <= chunk
        assert start == seq.n_cached
