"""Fault-injection + recovery tests (DESIGN.md §11, core/faults.py).

Fast subset (pure python / single cheap construct): FaultPlan
determinism and budgets, the FAILED lifecycle edges, cluster
fail/reboot, WarmPool entry integrity, the reserved-window leak
regression (thread hammer), and admission shedding plumbing.

Live-engine cases (ALSO marked slow+fleet): seeded TE kill mid-burst
with full recovery + greedy-token parity, mid-migration source crash
(at-most-once dedupe), transient transfer retry with backoff, fork
retry with an alternative source, drain-cancel racing a failure, and
``Scheduler.remove`` on a mid-migration sequence.
"""
import threading
import time
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.abstractions import RequestType, Status, UserRequest
from repro.core.faults import (AdmissionRejected, FaultPlan, FaultSpec,
                               ForkFault, TEFailureError, TransferFault,
                               backoff_s)
from repro.core.fleet import (FleetExecutor, LifecycleError, TEState,
                              advance)
from repro.core.cluster import TaskExecutor
from repro.core.scaling import WarmPool, WarmPoolMismatchError
from repro.core.scheduling import TEHandle
from repro.core.serving_plane import ServingJobEngine, TopologySpec
from repro.engine import EngineConfig, FlowServe, Request, SamplingParams
from repro.models import get_model

pytestmark = pytest.mark.faults

SP = SamplingParams(temperature=0.0, max_new_tokens=10, stop_on_eos=False)
LENS, RATIOS = [16, 64], [0.25, 1.0]
COLO_HEAT = -np.ones((2, 2))
PD_HEAT = np.ones((2, 2))


def _ecfg(**kw):
    base = dict(n_pages=64, page_size=8, max_batch_tokens=32,
                chunk_size=8, max_decode_batch=4)
    base.update(kw)
    return EngineConfig(**base)


def _plane(bundle, params, topo, heat=COLO_HEAT, **kw):
    return ServingJobEngine(bundle, params, topo, heatmap=heat,
                            prefill_lens=LENS, decode_ratios=RATIOS,
                            ecfg=_ecfg(), **kw)


def _prompts(n, length=14, seed0=0):
    return [[1] + [int(x) for x in
                   np.random.RandomState(seed0 + i).randint(3, 200, length)]
            for i in range(n)]


@pytest.fixture(scope="module")
def qwen():
    bundle = get_model("qwen3-8b", smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    return bundle, params


def _reference_tokens(bundle, params, prompts, sp=SP):
    ref = FlowServe(bundle, params, _ecfg(), name="fref")
    ids = [ref.add_request(Request(prompt_tokens=p, sampling=sp))
           for p in prompts]
    comps = {c.req_id: c.tokens for c in ref.run_to_completion()}
    return [comps[i] for i in ids]


def _fake_engine(name, steps=0, queued=False):
    sched = types.SimpleNamespace(
        queued_seqs=lambda: ([object()] if queued else []))
    return types.SimpleNamespace(name=name, steps=steps, scheduler=sched,
                                 fault_plan=None,
                                 distflow=types.SimpleNamespace(
                                     fault_hook=None))


# ---------------------------------------------------------------------------
# Fast: FaultPlan determinism + budgets
# ---------------------------------------------------------------------------


def test_fault_plan_seeded_and_deterministic():
    names = [f"te-{i}" for i in range(8)]
    picks_a = [FaultPlan(seed=s).choose_victim(names) for s in range(20)]
    picks_b = [FaultPlan(seed=s).choose_victim(names) for s in range(20)]
    assert picks_a == picks_b             # same seed -> same victim
    assert len(set(picks_a)) > 1          # different seeds spread victims
    # victim choice ignores caller-side ordering
    assert FaultPlan(seed=3).choose_victim(names) \
        == FaultPlan(seed=3).choose_victim(list(reversed(names)))
    with pytest.raises(ValueError):
        FaultPlan(specs=[FaultSpec("meteor_strike")])


def test_fault_plan_crash_at_step_and_count_budget():
    fp = FaultPlan(specs=[FaultSpec("te_crash", te="te-1", at_step=3)])
    # wrong TE never fires; right TE fires only once step >= at_step
    fp.on_step(_fake_engine("te-0", steps=5))
    fp.on_step(_fake_engine("te-1", steps=2))
    with pytest.raises(TEFailureError) as ei:
        fp.on_step(_fake_engine("te-1", steps=3))
    assert ei.value.te == "te-1"
    # budget consumed: the same TE steps on afterwards
    fp.on_step(_fake_engine("te-1", steps=4))
    assert fp.fired("te_crash") == 1
    assert fp.injected[0]["te"] == "te-1" and fp.injected[0]["step"] == 3


def test_fault_plan_phase_scoping_and_prefix_match():
    # a PREFILL-phase crash only fires while the engine holds queued work
    fp = FaultPlan(specs=[FaultSpec("te_crash", te="te-pd0",
                                    phase="prefill")])
    fp.on_step(_fake_engine("te-pd0-p", queued=False))   # decode-only: no
    with pytest.raises(TEFailureError):                  # prefix match +
        fp.on_step(_fake_engine("te-pd0-p", queued=True))  # queued work
    # migration/fork phases never fire from on_step
    fp2 = FaultPlan(specs=[FaultSpec("te_crash", te="a", phase="migration"),
                           FaultSpec("te_crash", te="a", phase="fork")])
    fp2.on_step(_fake_engine("a", queued=True))
    with pytest.raises(TEFailureError):
        fp2.on_migration(_fake_engine("a"), "b")
    with pytest.raises(TEFailureError):
        fp2.on_fork(_fake_engine("a"))


def test_fault_plan_transient_kinds_and_straggler():
    fp = FaultPlan(specs=[FaultSpec("xfer_fail", count=2),
                          FaultSpec("fork_fail", te="src"),
                          FaultSpec("straggler", te="slow", delay_s=0.02)])
    for _ in range(2):
        with pytest.raises(TransferFault):
            fp.xfer_hook("x", "y", 1024)
    fp.xfer_hook("x", "y", 1024)          # budget of 2 exhausted
    with pytest.raises(ForkFault):
        fp.on_fork(_fake_engine("src"))
    t0 = time.monotonic()
    fp.on_step(_fake_engine("slow"))      # stalls but does not die
    assert time.monotonic() - t0 >= 0.02
    assert fp.fired() == 4


def test_backoff_is_capped_exponential():
    delays = [backoff_s(i) for i in range(8)]
    assert delays[:4] == [0.005, 0.01, 0.02, 0.04]
    assert all(d == 0.1 for d in delays[5:])      # capped
    assert delays == sorted(delays)


# ---------------------------------------------------------------------------
# Fast: FAILED lifecycle edges
# ---------------------------------------------------------------------------


def test_failed_state_legal_and_illegal_transitions():
    # legal: fail from WARMING/SERVING/DRAINING; leave via reboot or release
    for frm in (TEState.WARMING, TEState.SERVING, TEState.DRAINING):
        assert advance(frm, TEState.FAILED) is TEState.FAILED
    assert advance(TEState.FAILED, TEState.WARMING) is TEState.WARMING
    assert advance(TEState.FAILED, TEState.RELEASED) is TEState.RELEASED
    # every other FAILED edge raises
    for frm in (TEState.PROVISIONING, TEState.RELEASED, TEState.FAILED):
        with pytest.raises(LifecycleError):
            advance(frm, TEState.FAILED)
    for to in (TEState.SERVING, TEState.DRAINING, TEState.PROVISIONING):
        with pytest.raises(LifecycleError):
            advance(TEState.FAILED, to)


def test_cluster_te_fail_and_reboot_walk():
    te = TaskExecutor("te-0", "colocated")
    assert te.state is TEState.SERVING
    te.fail()
    assert not te.healthy and te.state is TEState.FAILED
    te.reboot()                           # FAILED -> WARMING -> SERVING
    assert te.healthy and te.state is TEState.SERVING
    # failing a DRAINING TE quarantines it too
    te.transition(TEState.DRAINING)
    te.fail()
    assert te.state is TEState.FAILED
    te.transition(TEState.RELEASED)       # replace instead of reboot
    te.fail()                             # RELEASED stays released
    assert te.state is TEState.RELEASED


def test_tehandle_failed_stops_admitting():
    h = TEHandle("t", "colocated", state=TEState.SERVING)
    assert h.admitting
    h.transition(TEState.FAILED)
    assert not h.admitting


# ---------------------------------------------------------------------------
# Fast: WarmPool entry integrity
# ---------------------------------------------------------------------------


def test_warm_pool_hit_miss_and_tag_mismatch():
    pool = WarmPool(capacity_bytes=2000)
    params = {"w": np.zeros((8, 8), np.float32)}           # 256 B
    assert pool.put("qwen", params, host_copy=False, tag="qwen-8b")
    assert pool.get("llama") is None                       # miss
    assert pool.get("qwen", tag="qwen-8b") is params       # tagged hit
    assert pool.get("qwen") is params                      # untagged hit
    with pytest.raises(WarmPoolMismatchError):
        pool.get("qwen", tag="llama-70b")                  # wrong asset
    with pytest.raises(WarmPoolMismatchError):
        pool.put("qwen", params, host_copy=False, tag="llama-70b")
    assert pool.stats()["hits"] == 2 and pool.stats()["misses"] == 1
    # eviction clears the tag with the entry
    big = {"w": np.zeros((450,), np.float32)}              # 1800 B
    assert pool.put("other", big, host_copy=False)
    assert "qwen" not in pool.tags and not pool.hit("qwen")


def test_from_warm_rejects_mismatched_asset(qwen):
    bundle, params = qwen
    bogus = {"not_the_model": np.zeros((4, 4), np.float32)}
    with pytest.raises(WarmPoolMismatchError, match="does not match"):
        FlowServe.from_warm(bundle, bogus, _ecfg(), name="te-bad")
    # the real params still come up fine
    te = FlowServe.from_warm(bundle, jax.tree.map(np.asarray, params),
                             _ecfg(), name="te-good")
    assert te.fork_ready


# ---------------------------------------------------------------------------
# Fast: reserved-window leak regression (thread hammer)
# ---------------------------------------------------------------------------


def _window_plane():
    """A plane skeleton exposing ONLY the window allocator (no engines)."""
    je = ServingJobEngine.__new__(ServingJobEngine)
    je.topology = TopologySpec(colo=1, tp=1)
    je._offset_cursor = 0
    je._free_windows = []
    je._window_of = {}
    je._window_lock = threading.Lock()
    je._reserved_windows = set()
    return je


def test_window_abort_releases_reservation():
    je = _window_plane()
    off, owned = je._alloc_window()
    assert owned and off in je._reserved_windows
    je._abort_window(off, owned)          # the fork raised: no leak
    assert off not in je._reserved_windows
    off2, owned2 = je._alloc_window()
    assert owned2 and off2 == off         # the window is reusable
    je._commit_window("te-x", off2, owned2)
    assert je._window_of["te-x"] == off2
    # committing an UNOWNED fallback window must not clobber a live
    # reservation of offset 0
    je2 = _window_plane()
    off0, owned0 = je2._alloc_window()
    assert off0 == 0 and owned0
    je2._commit_window("te-fallback", 0, False)
    assert 0 in je2._reserved_windows     # the real claim survives
    je2._commit_window("te-real", off0, owned0)
    assert je2._window_of["te-real"] == 0


def test_window_leak_thread_hammer():
    """Concurrent forks that abort mid-bring-up must never shrink the
    fleet: after the hammer, every window is either committed or free and
    nothing stays reserved."""
    je = _window_plane()
    n_threads, iters = 8, 40
    errors = []

    def hammer(tid):
        rng = np.random.RandomState(tid)
        try:
            for i in range(iters):
                off, owned = je._alloc_window()
                if rng.rand() < 0.5:      # fork "raised" mid-bring-up
                    je._abort_window(off, owned)
                else:
                    name = f"te-{tid}-{i}"
                    je._commit_window(name, off, owned)
                    if owned:             # release it again (scale-in)
                        with je._window_lock:
                            je._free_windows.append(
                                je._window_of.pop(name))
        except Exception as exc:          # pragma: no cover
            errors.append(exc)

    threads = [threading.Thread(target=hammer, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert je._reserved_windows == set()  # nothing leaked
    assert not je._window_of              # everything released again
    # every window the cursor ever handed out is recoverable
    recovered = set()
    while True:
        off, owned = je._alloc_window()
        if not owned or off in recovered:
            break
        recovered.add(off)
    assert len(recovered) >= min(je._offset_cursor, 1)


# ---------------------------------------------------------------------------
# Fast: admission shedding plumbing
# ---------------------------------------------------------------------------


def test_admission_check_sheds_on_bounded_queue():
    je = ServingJobEngine.__new__(ServingJobEngine)
    je.admission_limit = 2
    je.steps = 0
    je.jobs, je.rejections, je._parked = {}, [], []
    eng = types.SimpleNamespace(load_metrics=lambda: {"n_queued": 3})
    h = TEHandle("te-0", "colocated", state=TEState.SERVING)
    h.engine = eng
    je._handles = [h]
    req = UserRequest(rtype=RequestType.CHAT,
                      payload={"tokens": [1, 2, 3], "max_new_tokens": 4})
    with pytest.raises(AdmissionRejected) as ei:
        je._check_admission(req)          # 3 queued >= 2 * 1 serving
    assert ei.value.req_id == req.req_id
    assert je.rejections[0]["cap"] == 2
    job = next(iter(je.jobs.values()))
    assert job.status is Status.REJECTED
    # capacity recovered (or queue drained): admission reopens
    eng2 = types.SimpleNamespace(load_metrics=lambda: {"n_queued": 0})
    h.engine = eng2
    je._check_admission(req)              # no raise
    # limit=None disables shedding entirely
    je.admission_limit = None
    h.engine = eng
    je._check_admission(req)


# ---------------------------------------------------------------------------
# Slow: live kill -> recovery with greedy-token parity
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.fleet
@pytest.mark.parametrize("threads", [0, 4])
def test_live_te_kill_recovers_all_requests_with_parity(qwen, threads):
    """Seeded kill of 1-of-3 TEs mid-burst: the plane completes 100% of
    requests exactly once (restarts counted), and every completion —
    including the restarted ones, which re-run from the prompt at
    temperature 0 — matches the no-fault reference tokens."""
    bundle, params = qwen
    prompts = _prompts(9)
    expect = _reference_tokens(bundle, params, prompts)

    fp = FaultPlan(seed=11)
    victim = fp.choose_victim([f"te-colo{i}" for i in range(3)])
    fp.add(FaultSpec("te_crash", te=victim, at_step=2))
    je = _plane(bundle, params, TopologySpec(colo=3),
                policy="round_robin", fault_plan=fp,
                fleet_threads=threads)
    try:
        rids = [je.submit(p, SP) for p in prompts]
        comps = je.run_to_completion()
        assert fp.fired("te_crash") == 1
        got = {}
        for c in comps:
            assert c.req_id not in got, "duplicated completion"
            got[c.req_id] = c.tokens
        assert sorted(got) == sorted(rids)          # none lost
        for rid, want in zip(rids, expect):
            assert got[rid] == want                 # greedy parity for ALL
        # containment surfaced in the plane's books
        ev = [e for e in je.scale_events if e["kind"] == "te_failure"]
        assert len(ev) == 1 and ev[0]["te_id"] == victim
        restarts = je.restart_counts()
        assert ev[0]["n_restarted"] == len(restarts) > 0
        assert all(r["reason"] == "te_failure" for r in je.resubmits)
        assert victim not in [h.te_id for h in je.handles]
        assert je.n_serving() == 2
        # repair: scale_to refills the lost capacity from survivors
        plan = je.scale_to(3)
        assert je.n_serving() == 3 and plan["tiers"]["fork"] >= 1
    finally:
        je.close()


@pytest.mark.slow
@pytest.mark.fleet
def test_mid_migration_source_crash_dedupes(qwen):
    """The source dies AFTER the destination imported (mid-migration):
    recovery must produce exactly one live copy — the voided import
    restarts once, never both endpoints."""
    bundle, params = qwen
    prompts = _prompts(4)
    # long decode budget: the fused hot loop emits up to decode_horizon
    # tokens per step, so a 10-token run would finish before the drain
    # pump gets a chance to migrate anything off the victim
    sp = SamplingParams(temperature=0.0, max_new_tokens=40,
                        stop_on_eos=False)
    expect = _reference_tokens(bundle, params, prompts, sp=sp)
    fp = FaultPlan(specs=[FaultSpec("te_crash", te="te-colo0",
                                    phase="migration")])
    je = _plane(bundle, params, TopologySpec(colo=2),
                policy="round_robin", fault_plan=fp)
    try:
        rids = [je.submit(p, sp) for p in prompts]
        for _ in range(3):
            je.step()
        je.drain("te-colo0")              # forces migrations off colo0
        je.run_to_completion()
        comps = {c.req_id: c.tokens for c in je.completions}
        assert fp.fired("te_crash") == 1
        assert sorted(comps) == sorted(rids)
        assert len(je.completions) == len(rids)   # exactly once, no dup
        for rid, want in zip(rids, expect):
            assert comps[rid] == want
        ev = [e for e in je.scale_events if e["kind"] == "te_failure"]
        assert len(ev) == 1 and ev[0]["te_id"] == "te-colo0"
    finally:
        je.close()


@pytest.mark.slow
@pytest.mark.fleet
def test_transient_transfer_fault_retries_with_backoff(qwen):
    """A transient wire failure on the PD handoff voids nothing: both
    endpoints restore state and the pump retries with capped backoff
    until the KV lands — every request still completes."""
    bundle, params = qwen
    prompts = _prompts(3)
    fp = FaultPlan(specs=[FaultSpec("xfer_fail", te="te-pd0-p", count=2)])
    je = _plane(bundle, params, TopologySpec(pd=1, colo=0), heat=PD_HEAT,
                fault_plan=fp)
    try:
        rids = [je.submit(p, SP) for p in prompts]
        comps = {c.req_id for c in je.run_to_completion()}
        assert comps == set(rids)
        assert fp.fired("xfer_fail") == 2
        assert je.xfer_retries == 2       # each fault parked + retried
        assert je._xfer_retry == {}       # all backoffs resolved
    finally:
        je.close()


@pytest.mark.slow
@pytest.mark.fleet
def test_fork_retries_transient_fault_and_alternative_source(qwen):
    bundle, params = qwen
    # transient ForkFault: the same scale-out retries and succeeds
    fp = FaultPlan(specs=[FaultSpec("fork_fail", count=1)])
    je = _plane(bundle, params, TopologySpec(colo=2), fault_plan=fp)
    try:
        je._scale_out()
        assert fp.fired("fork_fail") == 1
        assert je.n_serving() == 3        # retry from the next source won
        assert je._reserved_windows == set()
    finally:
        je.close()
    # fork SOURCE dies mid-fork: quarantined, alternative source finishes
    fp2 = FaultPlan(specs=[FaultSpec("te_crash", te="te-colo0",
                                     phase="fork")])
    je2 = _plane(bundle, params, TopologySpec(colo=2), fault_plan=fp2)
    try:
        je2._scale_out()
        assert fp2.fired("te_crash") == 1
        names = [h.te_id for h in je2.handles]
        assert "te-colo0" not in names    # the dead source left the fleet
        assert je2.n_serving() == 2       # lost 1, forked 1
        assert any(e["kind"] == "te_failure" for e in je2.scale_events)
        assert je2._reserved_windows == set()
    finally:
        je2.close()


@pytest.mark.slow
@pytest.mark.fleet
def test_drain_cancel_races_concurrent_failure(qwen):
    """Drain-cancel on TE A in the same step window as TE B failing: B is
    quarantined, its work parks (A is DRAINING — no admitting survivor
    exists), the cancel lands (A serves again), and the parked work
    flushes onto A so every request still completes exactly once."""
    bundle, params = qwen
    prompts = _prompts(6)
    fp = FaultPlan(specs=[FaultSpec("te_crash", te="te-colo1", at_step=0)])
    je = _plane(bundle, params, TopologySpec(colo=2),
                policy="round_robin", fault_plan=fp)
    try:
        rids = [je.submit(p, SP) for p in prompts]
        je.drain("te-colo0")
        je.step()                         # colo1 crashes mid-drain of colo0
        assert "te-colo1" not in [h.te_id for h in je.handles]
        assert je._parked                 # no admitting survivor yet
        h0 = next(h for h in je.handles if h.te_id == "te-colo0")
        assert h0.state is TEState.DRAINING   # the drain could not finish
        je.cancel_drain("te-colo0")       # resurgence: the drain reverses
        assert h0.state is TEState.SERVING
        je.run_to_completion()
        comps = {c.req_id for c in je.completions}
        assert comps == set(rids)
        assert len(je.completions) == len(rids)   # exactly once
        assert not je._parked
        assert any(r["from"] == "parked" for r in je.resubmits)
    finally:
        je.close()


@pytest.mark.slow
@pytest.mark.fleet
def test_scheduler_remove_on_mid_migration_sequence(qwen):
    """``Scheduler.remove`` on a sequence whose KV import is still in
    flight must leave the destination consistent: the pending handle is
    void, pages release, and the engine keeps serving other work."""
    bundle, params = qwen
    src = FlowServe(bundle, params, _ecfg(mode="prefill"), name="srcte")
    dst = FlowServe(bundle, params, _ecfg(mode="decode"), name="dstte")
    src.distflow.link_cluster([dst.distflow])
    rid = src.add_request(Request(prompt_tokens=_prompts(1)[0], sampling=SP))
    ready = []
    while not ready:
        src.step()
        ready = src.pop_migratable()
    assert ready == [rid]
    src.migrate_out(rid, dst, overlap=True)     # async: _kv_pending set
    seq = dst._seqs[rid]
    assert "_kv_pending" in seq.extra
    free_before = dst.pool.free_page_count()
    dst.scheduler.remove(seq)
    seq.extra.pop("_kv_pending", None)          # voided, never scattered
    dst.release_request(rid, keep_prefix=False)
    assert rid not in dst._seqs and not dst.has_work()
    assert dst.pool.free_page_count() > free_before
    # the pair still serves fresh work afterwards (full PD handoff)
    rid2 = src.add_request(Request(prompt_tokens=_prompts(1, seed0=9)[0],
                                   sampling=SP))
    ready = []
    while not ready:
        src.step()
        ready = src.pop_migratable()
    src.migrate_out(rid2, dst, overlap=False)
    comps = dst.run_to_completion()
    assert [c.req_id for c in comps] == [rid2]


@pytest.mark.slow
@pytest.mark.fleet
def test_admission_sheds_live_and_reopens_after_repair(qwen):
    """Graceful degradation end to end: capacity loss shrinks the
    admission bound, excess submits are REJECTED (not queued), and the
    accepted backlog still completes."""
    bundle, params = qwen
    fp = FaultPlan(specs=[FaultSpec("te_crash", te="te-colo1", at_step=0)])
    je = _plane(bundle, params, TopologySpec(colo=2),
                policy="round_robin", fault_plan=fp, admission_limit=2)
    try:
        accepted = [je.submit(p, SP) for p in _prompts(3)]
        je.step()                         # colo1 dies; its work restarts
        assert je.n_serving() == 1
        with pytest.raises(AdmissionRejected):
            for p in _prompts(8, seed0=50):
                accepted.append(je.submit(p, SP))
        assert je.rejections and je.rejections[-1]["n_serving"] == 1
        accepted = [r for r in accepted if r in je.requests]
        comps = {c.req_id for c in je.completions + je.run_to_completion()}
        assert set(accepted) <= comps     # accepted work all completes
        rejected_jobs = [j for j in je.jobs.values()
                         if j.status is Status.REJECTED]
        assert len(rejected_jobs) == len(je.rejections)
    finally:
        je.close()
