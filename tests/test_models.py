"""Per-architecture smoke tests (deliverable f): every assigned arch at a
reduced config runs forward + one train step on CPU, asserts output shapes
and no NaNs; prefill+decode must match the teacher-forced forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_configs, shape_applicable, smoke_config
from repro.models import get_model
from repro.training import OptimizerConfig, TrainConfig, make_train_step, init_opt_state

ARCHS = list_configs()


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    m = get_model(arch, smoke=True)
    cfg = m.cfg
    params = m.init_params(jax.random.PRNGKey(0), jnp.float32)
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    logits = m.forward(cfg, params, tokens, attn_impl="naive",
                       **m.extra_inputs(B, jnp.float32))
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nan(arch):
    m = get_model(arch, smoke=True)
    params = m.init_params(jax.random.PRNGKey(0), jnp.float32)
    step = jax.jit(make_train_step(m, TrainConfig(
        opt=OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=10))))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, m.cfg.vocab_size)
    targets = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, m.cfg.vocab_size)
    mask = jnp.ones((B, S), jnp.float32)
    extra = {k: jnp.asarray(v) for k, v in m.extra_inputs(B, jnp.float32).items()}
    params2, opt, metrics = step(params, init_opt_state(params), tokens,
                                 targets, mask, extra)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(params2)[0]
    assert not np.allclose(np.asarray(l0, np.float32), np.asarray(l1, np.float32))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    m = get_model(arch, smoke=True)
    cfg = m.cfg
    params = m.init_params(jax.random.PRNGKey(0), jnp.float32)
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    extra = m.extra_inputs(B, jnp.float32)
    logits_tf = m.forward(cfg, params, tokens, attn_impl="naive", **extra)
    cache = m.init_cache(B, 32, jnp.float32)
    lg, cache = m.prefill(cfg, params, tokens[:, :8], cache, **extra)
    errs = [float(np.max(np.abs(np.asarray(lg - logits_tf[:, 7], np.float32))))]
    for t in range(8, S):
        lg, cache = m.decode_step(cfg, params, tokens[:, t], cache)
        errs.append(float(np.max(np.abs(np.asarray(lg - logits_tf[:, t], np.float32)))))
    assert max(errs) < 2e-3, errs


def test_all_cells_defined():
    """40 (arch × shape) cells exist; long_500k skips only full-attention."""
    cells = [(a, s) for a in ARCHS for s in SHAPES]
    assert len(cells) == 40
    skipped = [(a, s) for a, s in cells
               if not shape_applicable(get_config(a), SHAPES[s])[0]]
    # pure full-attention archs skip long_500k (granite's MoE FFN does not
    # change its full-attention KV growth; mixtral runs thanks to SWA)
    assert sorted({a for a, _ in skipped}) == [
        "granite-moe-3b-a800m", "llama-3.2-vision-11b", "nemotron-4-15b",
        "qwen3-8b", "seamless-m4t-large-v2"]
    assert all(s == "long_500k" for _, s in skipped)


def test_param_counts_match_published():
    expect = {"gemma2-9b": 9.2, "qwen3-8b": 8.2, "mixtral-8x7b": 46.7,
              "nemotron-4-15b": 15.6, "recurrentgemma-2b": 2.7}
    for arch, billions in expect.items():
        n = get_config(arch).param_count() / 1e9
        assert abs(n - billions) / billions < 0.08, (arch, n)
