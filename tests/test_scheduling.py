"""Distributed scheduling (§5, Algorithm 1) + heatmap + predictor tests."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (DecodeLengthPredictor, DistributedScheduler,
                        GlobalPromptTree, PredictorConfig, SchedRequest,
                        TEHandle, round_robin_scheduler, synth_trace,
                        train_predictor)
from repro.core.heatmap import HeatmapStudy, lookup


@pytest.fixture(scope="module")
def heat():
    return HeatmapStudy(get_config("qwen3-8b"))


def _tes():
    return [TEHandle("c0", "colocated"), TEHandle("c1", "colocated"),
            TEHandle("p0", "pd_pair"), TEHandle("p1", "pd_pair")]


@pytest.mark.slow
def test_heatmap_directions(heat):
    g = heat.combined()
    # long prefill, short decode => PD-disaggregated wins (positive)
    assert lookup(g, heat.prefill_lens, heat.decode_ratios, 8192, 400) > 0
    # the paper: disagg advantage (dark red) is larger than colo advantage
    assert g.max() > -g.min()


@pytest.mark.slow
def test_heatmap_stability(heat):
    # paper: >80% of cells keep a consistent sign across RPS values
    assert heat.stability() >= 0.8


@pytest.mark.slow
def test_pd_aware_selects_type(heat):
    ds = DistributedScheduler(_tes(), heat.combined(), heat.prefill_lens,
                              heat.decode_ratios)
    long_prefill = SchedRequest(tokens=list(range(8192)), predicted_decode=256)
    sub = ds.pd_aware(long_prefill, list(ds.tes.values()))
    assert {t.te_type for t in sub} == {"pd_pair"}


@pytest.mark.slow
def test_locality_prefers_prefix_holder(heat):
    tes = _tes()
    ds = DistributedScheduler(tes, heat.combined(), heat.prefill_lens,
                              heat.decode_ratios)
    prompt = list(range(100, 164))
    ds.commit(SchedRequest(tokens=prompt), tes[1])          # c1 holds prefix
    req = SchedRequest(tokens=prompt + [7, 8, 9])
    chosen = ds.locality_aware(req, [tes[0], tes[1]])
    assert chosen.te_id == "c1"


@pytest.mark.slow
def test_load_aware_fallback_when_unbalanced(heat):
    tes = _tes()
    ds = DistributedScheduler(tes, heat.combined(), heat.prefill_lens,
                              heat.decode_ratios)
    tes[0].load = 1000.0
    tes[1].load = 10.0
    req = SchedRequest(tokens=list(range(50)))
    # group is unbalanced: dist_sched must go load-aware
    chosen = ds.dist_sched(req)
    assert chosen.load <= min(t.load for t in ds.tes.values()) + 1e-9


def test_round_robin_cycles(heat):
    tes = _tes()
    rr = round_robin_scheduler(tes)
    picks = [rr(SchedRequest(tokens=[1])).te_id for _ in range(8)]
    assert picks[:4] == [t.te_id for t in tes]
    assert picks[4:] == picks[:4]


def test_complete_releases_actual_consumption():
    """ISSUE-4 bugfix: a request predicted at 8 decode tokens actually
    decoded 20. Callers that track real progress (the live plane's
    ``refresh``, sims decaying load per generated token) fold the extra
    work into ``te.load``; completion must release the ACTUAL consumption
    — subtracting the stale prediction leaves +12 phantom tokens behind
    per request, drifting the load signal upward over a long run."""
    ds = DistributedScheduler([TEHandle("a", "colocated")], np.ones((1, 1)),
                              [16], [1.0])
    te = ds.tes["a"]
    for _ in range(25):
        req = SchedRequest(tokens=list(range(10)), predicted_decode=8)
        ds.commit(req, te)
        te.load += 20 - req.predicted_decode   # live signal: decode ran long
        ds.complete(req, te, actual_decode=20)
    assert te.load == 0.0
    # without the observed length the prediction is still the fallback
    req = SchedRequest(tokens=list(range(10)), predicted_decode=8)
    ds.commit(req, te)
    ds.complete(req, te)
    assert te.load == 0.0
    # and over-release clamps at zero instead of going negative
    te.load = 5.0
    ds.complete(SchedRequest(tokens=[1, 2], predicted_decode=0), te,
                actual_decode=100)
    assert te.load == 0.0


def test_global_prompt_tree_longest_match():
    gt = GlobalPromptTree()
    gt.record([1, 2, 3, 4], "a")
    gt.record([1, 2, 9, 9, 9], "b")
    best, n = gt.best_te([1, 2, 3, 4, 5], [TEHandle("a", "colocated"),
                                           TEHandle("b", "colocated")])
    assert best == "a" and n == 4


def test_predictor_accuracy_target():
    """§5.3.3: paper reports 84.9%; our synthetic-trace target is >= 0.8."""
    cfg = PredictorConfig(steps=250)
    xs, ys, _ = synth_trace(3000, cfg)
    params, acc = train_predictor(cfg, xs, ys)
    assert acc >= 0.80, acc
    pred = DecodeLengthPredictor(cfg, params)
    b = pred.predict_bucket(np.asarray([123, 125, 40, 41] * 30))
    assert 0 <= b < cfg.n_buckets
