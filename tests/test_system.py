"""End-to-end system tests: the FLOWSERVE engine against a pure decode
oracle, PD-disaggregated migration, RTC prefix caching + tiering, and the
JE/cluster-manager wiring (deliverable c, integration level)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine import EngineConfig, FlowServe, Request, SamplingParams
from repro.engine.distflow import BufferInfo
from repro.models import get_model

SP = SamplingParams(temperature=0.0, max_new_tokens=6, stop_on_eos=False)


@pytest.fixture(scope="module")
def qwen():
    bundle = get_model("qwen3-8b", smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    return bundle, params


def _oracle(bundle, params, prompt, n_new):
    cfg = bundle.cfg
    cache = bundle.init_cache(1, 128, jnp.float32)
    extra = bundle.extra_inputs(1, jnp.float32)
    if len(prompt) > 1:
        _, cache = bundle.prefill(cfg, params,
                                  jnp.asarray([prompt[:-1]], jnp.int32),
                                  cache, **extra)
    out, cur = [], prompt[-1]
    for _ in range(n_new):
        lg, cache = bundle.decode_step(cfg, params,
                                       jnp.asarray([cur], jnp.int32), cache)
        lg = jnp.where(jnp.arange(lg.shape[-1])[None] >= cfg.vocab_size,
                       -1e30, lg.astype(jnp.float32))
        cur = int(jnp.argmax(lg[0]))
        out.append(cur)
    return out


def _prompts(n, length=11, seed0=0):
    return [[1] + [int(x) for x in
                   np.random.RandomState(seed0 + i).randint(3, 200, length)]
            for i in range(n)]


def _engine(bundle, params, mode="colocated", **kw):
    ecfg = EngineConfig(mode=mode, n_pages=64, page_size=8, n_slots=4,
                        max_len=96, max_batch_tokens=32, chunk_size=8,
                        max_decode_batch=4, **kw)
    return FlowServe(bundle, params, ecfg, name=f"te-{mode}")


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen3-8b", "mixtral-8x7b", "rwkv6-1.6b",
                                  "recurrentgemma-2b", "seamless-m4t-large-v2"])
def test_engine_matches_oracle(arch):
    bundle = get_model(arch, smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    eng = _engine(bundle, params)
    prompts = _prompts(3)
    ids = [eng.add_request(Request(prompt_tokens=p, sampling=SP)) for p in prompts]
    comps = {c.req_id: c for c in eng.run_to_completion()}
    assert len(comps) == 3
    for p, rid in zip(prompts, ids):
        assert comps[rid].tokens == _oracle(bundle, params, p, 6), arch


def test_prefix_cache_hit_and_reuse(qwen):
    bundle, params = qwen
    eng = _engine(bundle, params)
    p = _prompts(1, length=20)[0]
    eng.add_request(Request(prompt_tokens=p, sampling=SP))
    eng.run_to_completion()
    rid2 = eng.add_request(Request(prompt_tokens=p, sampling=SP))
    comps = {c.req_id: c for c in eng.run_to_completion()}
    st = eng.prefix_cache_stats()
    assert st["hits"] >= 1 and st["tokens_reused"] >= 8
    assert comps[rid2].tokens == _oracle(bundle, params, p, 6)


@pytest.mark.slow
def test_rtc_dram_tier_populate(qwen):
    bundle, params = qwen
    eng = _engine(bundle, params)
    p = _prompts(1, length=30)[0]
    eng.add_request(Request(prompt_tokens=p, sampling=SP))
    eng.run_to_completion()
    # swap the preserved prefix to DRAM; a repeat request must populate it
    leaves = eng.rtc.tree.leaves_by_lru()
    assert leaves
    entry = leaves[0].payload
    eng.rtc.copy_to_dram(entry)
    assert entry.location == "dram"
    # tiny smoke models recompute faster than any fetch — force the cost
    # model toward fetch so the populate path is exercised
    eng.rtc.cost.flops_per_token = 1e12
    rid = eng.add_request(Request(prompt_tokens=p, sampling=SP))
    comps = {c.req_id: c for c in eng.run_to_completion()}
    assert comps[rid].tokens == _oracle(bundle, params, p, 6)
    assert eng.rtc.stats["populates"] >= 1


@pytest.mark.slow
def test_preemption_under_page_pressure(qwen):
    bundle, params = qwen
    sp = SamplingParams(temperature=0.0, max_new_tokens=40, stop_on_eos=False)
    prompts = _prompts(4, length=16)
    eng = FlowServe(bundle, params,
                    EngineConfig(mode="colocated", n_pages=14, page_size=8,
                                 max_batch_tokens=32, chunk_size=8,
                                 max_decode_batch=4,
                                 enable_prefix_cache=False))
    ids = [eng.add_request(Request(prompt_tokens=p, sampling=sp)) for p in prompts]
    comps = {c.req_id: c for c in eng.run_to_completion(max_steps=20000)}
    assert len(comps) == 4          # everything completes despite preemption
    for p, rid in zip(prompts, ids):
        assert comps[rid].tokens == _oracle(bundle, params, p, 40)


@pytest.mark.slow
def test_pd_disaggregated_equals_oracle(qwen):
    bundle, params = qwen
    prompts = _prompts(3, length=14)
    pe = _engine(bundle, params, mode="prefill")
    de = _engine(bundle, params, mode="decode")
    pe.distflow.link_cluster([de.distflow])
    for p in prompts:
        pe.add_request(Request(prompt_tokens=p, sampling=SP))
    comps = {}
    for _ in range(5000):
        if not (pe.has_work() or de.has_work()) and not pe._prefill_done_buffer:
            break
        pe.step()
        for rid in pe.pop_migratable():
            payload = pe.export_kv(rid)
            pe.distflow.transfer(
                BufferInfo(owner=pe.name, tier="npu", payload=payload),
                BufferInfo(owner=de.name, tier="npu",
                           deliver=lambda pl: de.import_request(pl)))
            pe.release_request(rid, keep_prefix=False)
        for c in de.step():
            comps[c.req_id] = c
    assert len(comps) == 3
    for i, p in enumerate(prompts):
        match = [c for c in comps.values()
                 if c.n_prompt == len(p)
                 and c.tokens == _oracle(bundle, params, p, 6)]
        assert match, f"prompt {i} has no matching completion"
    assert pe.distflow.bytes_moved() > 0


def test_async_vs_sync_same_output(qwen):
    bundle, params = qwen
    prompts = _prompts(4)
    outs = []
    for async_sched in (False, True):
        eng = _engine(bundle, params, async_sched=async_sched)
        ids = [eng.add_request(Request(prompt_tokens=p, sampling=SP))
               for p in prompts]
        comps = {c.req_id: c for c in eng.run_to_completion()}
        outs.append([comps[r].tokens for r in ids])
    assert outs[0] == outs[1]


@pytest.mark.slow
def test_je_cluster_wiring(qwen):
    """Request → JE decompose → TE dispatch → completions (§3 wiring)."""
    bundle, params = qwen
    from repro.configs import get_config
    from repro.core import (DistributedScheduler, RequestType, TEHandle,
                            UserRequest)
    from repro.core.cluster import JobExecutor
    from repro.core.heatmap import HeatmapStudy
    hs = HeatmapStudy(get_config("qwen3-8b"))
    te0 = TEHandle("te-0", "colocated", engine=_engine(bundle, params))
    te1 = TEHandle("te-1", "colocated", engine=_engine(bundle, params))
    ds = DistributedScheduler([te0, te1], hs.combined(), hs.prefill_lens,
                              hs.decode_ratios)
    dispatched = []

    def dispatch(task, te):
        dispatched.append((task.kind.value, te.te_id))
        te.engine.add_request(Request(prompt_tokens=task.payload["tokens"],
                                      sampling=SP))

    je = JobExecutor("je-0", ds, dispatch)
    for p in _prompts(4):
        je.handle(UserRequest(RequestType.CHAT, {"tokens": p}))
    total = sum(len(te.engine.run_to_completion()) for te in (te0, te1))
    assert total == 4
    assert len(dispatched) == 4


def test_checkpoint_roundtrip(tmp_path):
    import os
    from repro.training import CheckpointManager
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16),
                  "step": jnp.asarray(7, jnp.int32)}}
    cm = CheckpointManager(str(tmp_path), n_shards=2, keep=2)
    cm.save(1, tree)
    cm.save(2, jax.tree.map(lambda a: a * 2 if a.dtype != jnp.int32 else a, tree),
            blocking=False)
    cm.wait()
    assert cm.list_steps() == [1, 2]
    restored = cm.restore(tree)                 # latest = step 2
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.asarray(tree["a"]) * 2)
    r1 = cm.restore(tree, step=1)
    np.testing.assert_allclose(np.asarray(r1["a"]), np.asarray(tree["a"]))
    # gc keeps only the last `keep`
    cm.save(3, tree)
    assert cm.list_steps() == [2, 3]


def test_train_resume_equivalence(tmp_path):
    """Fault tolerance: crash after step N + resume == uninterrupted run."""
    from repro.data import DataConfig, PackedDataset
    from repro.training import (CheckpointManager, OptimizerConfig,
                                TrainConfig, train)
    bundle = get_model("h2o-danube-3-4b", smoke=True)
    params0 = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    dcfg = DataConfig(seq_len=16, batch_size=2, n_docs=64)
    opt = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=8)

    def data():
        return PackedDataset(dcfg).batches(epochs=100)

    tc_full = TrainConfig(steps=8, log_every=100, ckpt_every=100, opt=opt)
    p_full, _ = train(bundle, params0, data(), tc_full, log=lambda s: None)

    ck = CheckpointManager(str(tmp_path))
    tc_half = TrainConfig(steps=4, log_every=100, ckpt_every=4, opt=opt)
    train(bundle, params0, data(), tc_half, ckpt=ck, log=lambda s: None)
    # "crash": restart from the checkpoint; the pipeline is deterministic,
    # so skip the first 4 batches the same way the first half consumed them
    it = data()
    for _ in range(4):
        next(it)
    tc_rest = TrainConfig(steps=8, log_every=100, ckpt_every=100, opt=opt)
    p_res, _ = train(bundle, params0, it, tc_rest, ckpt=ck, resume=True,
                     log=lambda s: None)
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)
