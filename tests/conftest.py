"""Force a multi-device host platform BEFORE jax initializes, so the SPMD
tensor-parallel engine tests (tests/test_tp_engine.py) can build real 1×tp
meshes on CPU. Harmless for single-device tests: plain jits still run on
device 0. Conftest is imported before any test module, which is the only
reliable place to set XLA_FLAGS under plain `python -m pytest`.
"""
import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = \
        (_flags + " --xla_force_host_platform_device_count=8").strip()
