"""Cold-start ladder + fork-tree mass scale-out tests (DESIGN.md §10).

Fast subset: WarmPool accounting (hit/miss/LRU eviction), the tier cost
model, the deficit-reporting scale-out trigger, drain-resurgence, the
window allocator's reservation protocol under concurrent fork rounds,
and structural ``scale_to`` smokes (round counts, placement) — none of
which serve tokens, so no jit compiles. The end-to-end ladder tests
(fork-tree serving parity, released-params → warm-tier scale-out,
drain-cancel on resurgence, mid-PREFILL re-submission) spin live
engines and live in the slow lane (markers: ``slow`` + ``fleet``).
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fleet import TEState
from repro.core.scaling import (DrainTrigger, DRAMPageCache, FastScaler,
                                LoadSpreadTrigger, ModelAsset, WarmPool,
                                tier_seconds)
from repro.core.serving_plane import ServingJobEngine, TopologySpec
from repro.engine import EngineConfig, FlowServe, Request, SamplingParams
from repro.models import get_model

SP = SamplingParams(temperature=0.0, max_new_tokens=10, stop_on_eos=False)
LENS, RATIOS = [16, 64], [0.25, 1.0]
COLO_HEAT = -np.ones((2, 2))


def _ecfg(**kw):
    base = dict(n_pages=64, page_size=8, max_batch_tokens=32,
                chunk_size=8, max_decode_batch=4)
    base.update(kw)
    return EngineConfig(**base)


def _plane(bundle, params, topo, **kw):
    return ServingJobEngine(bundle, params, topo, heatmap=COLO_HEAT,
                            prefill_lens=LENS, decode_ratios=RATIOS,
                            ecfg=_ecfg(), **kw)


def _prompts(n, length=14, seed0=0):
    return [[1] + [int(x) for x in
                   np.random.RandomState(seed0 + i).randint(3, 200, length)]
            for i in range(n)]


@pytest.fixture(scope="module")
def qwen():
    bundle = get_model("qwen3-8b", smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    return bundle, params


def _reference_tokens(bundle, params, prompts, sp=SP):
    ref = FlowServe(bundle, params, _ecfg(), name="sref")
    ids = [ref.add_request(Request(prompt_tokens=list(p), sampling=sp))
           for p in prompts]
    comps = {c.req_id: c.tokens for c in ref.run_to_completion()}
    return [comps[i] for i in ids]


# ---------------------------------------------------------------------------
# Fast: WarmPool accounting
# ---------------------------------------------------------------------------


def _params(kb: int, seed: int = 0):
    return {"w": np.full((kb, 256), float(seed), np.float32)}  # kb * 1 KiB


def test_warm_pool_hit_miss_and_lru_eviction():
    pool = WarmPool(capacity_bytes=3 * 1024 * 1024)
    assert pool.get("a") is None                      # miss on empty
    assert pool.misses == 1
    assert pool.put("a", _params(1024, 1))
    assert pool.put("b", _params(1024, 2))
    assert pool.put("c", _params(1024, 3))
    assert pool.used() == 3 * 1024 * 1024
    # a hit refreshes LRU order: touch "a" so "b" is now the LRU victim
    assert pool.get("a") is not None
    assert pool.hits == 1
    assert pool.put("d", _params(1024, 4))            # evicts exactly "b"
    assert pool.evictions == 1
    assert pool.bytes_evicted == 1024 * 1024
    assert not pool.hit("b") and pool.hit("a") and pool.hit("c")
    # hit() is a non-counting peek; stats() reflects the full history
    hits = pool.hits
    pool.hit("a")
    assert pool.hits == hits
    s = pool.stats()
    assert (s["hits"], s["misses"], s["evictions"]) == (1, 1, 1)
    assert s["resident"] == 3


def test_warm_pool_rejects_oversize_and_reput_is_lru_touch():
    pool = WarmPool(capacity_bytes=1024 * 1024)
    assert not pool.put("huge", _params(2048))        # never partially resident
    assert pool.used() == 0
    assert pool.put("a", _params(512, 1))
    before = pool.used()
    assert pool.put("a", _params(512, 9))             # re-put: touch, not copy
    assert pool.used() == before
    assert float(pool.get("a")["w"][0, 0]) == 1.0     # original entry kept


def test_warm_pool_put_materializes_host_copy():
    pool = WarmPool()
    dev = {"w": jnp.ones((8, 8))}
    assert pool.put("m", dev, host_copy=True)
    got = pool.get("m")
    assert isinstance(got["w"], np.ndarray)


# ---------------------------------------------------------------------------
# Fast: tier cost model + triggers
# ---------------------------------------------------------------------------


def test_tier_seconds_orders_the_ladder():
    asset = ModelAsset("m", n_bytes=int(16e9), tp=1)
    fork, warm, cold = (tier_seconds(asset, t)
                        for t in ("fork", "warm", "cold"))
    assert fork < warm < cold                 # ICI fork < PCIe warm < SSD cold
    assert fork == pytest.approx(16e9 / 50e9)
    # tp shards the per-TE bytes
    sharded = ModelAsset("m", n_bytes=int(16e9), tp=4)
    assert tier_seconds(sharded, "fork") == pytest.approx(fork / 4)


def test_load_spread_trigger_reports_deficit():
    trig = LoadSpreadTrigger(threshold=0.5, patience=1, min_load=1.0,
                             te_capacity=10.0)
    # 2 TEs carrying 50 tokens of work need ceil(50/10)=5 TEs: deficit 3
    assert trig.observe([40.0, 10.0]) == 3
    assert trig.last_deficit == 3
    # one-shot: disarmed until the spread recovers below threshold
    assert trig.observe([40.0, 10.0]) == 0
    # without te_capacity the contract degrades to the old fork-one bool
    legacy = LoadSpreadTrigger(threshold=0.5, patience=1, min_load=1.0)
    assert legacy.observe([40.0, 10.0]) == 1
    assert not legacy.observe([5.0, 5.0])     # 0 is falsy (bool-compatible)


def test_drain_trigger_resurgent():
    trig = DrainTrigger(low_watermark=2.0, resurge_factor=4.0)
    assert not trig.resurgent([])
    assert not trig.resurgent([1.0, 2.0])     # mean 1.5 <= 8.0
    assert trig.resurgent([10.0, 12.0])       # mean 11 > 8.0


# ---------------------------------------------------------------------------
# Fast: window allocator reservation protocol (concurrent fork rounds)
# ---------------------------------------------------------------------------


def test_alloc_window_concurrent_uniqueness(qwen):
    """A round of concurrent forks allocates windows from executor threads
    BEFORE any of them registers: every owned offset must be unique, and
    reservations must clear once the TEs commit."""
    bundle, params = qwen
    je = _plane(bundle, params, TopologySpec(pd=0, colo=1))
    got, lock = [], threading.Lock()

    def grab():
        off, owned = je._alloc_window()
        with lock:
            got.append((off, owned))

    threads = [threading.Thread(target=grab) for _ in range(7)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    owned = [off for off, ok in got if ok]
    assert len(owned) == len(set(owned)), "duplicate window handed out"
    assert set(owned) <= set(range(1, jax.device_count()))
    for i, (off, ok) in enumerate(got):
        je._commit_window(f"te-x{i}", off, ok)
    assert je._reserved_windows == set()


def test_alloc_window_skips_reserved_freelist_entry(qwen):
    """Regression: a release landing mid-round pushes an offset onto the
    free list while an in-flight fork still holds its reservation — the
    next allocation must NOT re-hand that offset."""
    bundle, params = qwen
    je = _plane(bundle, params, TopologySpec(pd=0, colo=1))
    off, owned = je._alloc_window()
    assert owned and off in je._reserved_windows
    je._free_windows.append(off)              # stale/racing free-list entry
    off2, owned2 = je._alloc_window()
    assert owned2 and off2 != off
    # the stale entry is dropped (its holder will commit that window), so
    # a third allocation can't double-assign it either
    assert off not in je._free_windows
    off3, owned3 = je._alloc_window()
    assert owned3 and off3 not in (off, off2)


# ---------------------------------------------------------------------------
# Fast: structural scale_to smokes (no serving, no compiles)
# ---------------------------------------------------------------------------


def test_scale_to_two_te_smoke(qwen):
    bundle, params = qwen
    je = _plane(bundle, params, TopologySpec(pd=0, colo=1))
    plan = je.scale_to(2)
    assert plan["n_serving"] == je.n_serving() == 2
    assert len(plan["rounds"]) == 1
    assert plan["tiers"] == {"fork": 1, "warm": 0, "cold": 0}
    assert plan["rounds"][0]["sources"] == ["te-colo0"]
    assert je.scheduler.tes["te-scale0"].state is TEState.SERVING
    offs = list(je._window_of.values())
    assert len(offs) == len(set(offs)) == 2
    je.close()


def test_fork_tree_round_counts(qwen):
    """1→8 doubles per round (3 rounds of 1/2/4 forks), while the serial
    baseline takes N-1 = 7 rounds to the same fleet size."""
    bundle, params = qwen
    je = _plane(bundle, params, TopologySpec(pd=0, colo=1))
    plan = je.scale_to(8)
    assert [len(r["tes"]) for r in plan["rounds"]] == [1, 2, 4]
    assert plan["tiers"]["fork"] == 7
    assert je.n_serving() == 8
    offs = list(je._window_of.values())
    assert sorted(offs) == list(range(8))     # disjoint windows, no fallback
    je.close()
    je = _plane(bundle, params, TopologySpec(pd=0, colo=1))
    serial = je.scale_to(8, fan_out=False)
    assert [len(r["tes"]) for r in serial["rounds"]] == [1] * 7
    assert je.n_serving() == 8
    je.close()


# ---------------------------------------------------------------------------
# Slow: the ladder end to end on live engines
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.fleet
def test_fork_tree_serving_parity(qwen):
    """Greedy tokens through a freshly grown fork tree == the single-TE
    reference; round-robin placement exercises every forked TE."""
    bundle, params = qwen
    prompts = _prompts(8)
    je = _plane(bundle, params, TopologySpec(pd=0, colo=1),
                policy="round_robin")
    je.scale_to(4)
    from repro.core.scheduling import round_robin_scheduler
    je._rr = round_robin_scheduler(je._handles)
    rids = [je.submit(list(p), sampling=SP) for p in prompts]
    comps = {c.req_id: c.tokens for c in je.run_to_completion()}
    assert len(comps) == len(prompts)
    assert [comps[r] for r in rids] == _reference_tokens(bundle, params,
                                                         prompts)
    assert all(e.decode_steps > 0 for e in je.engines)
    je.close()


@pytest.mark.slow
@pytest.mark.fleet
def test_release_feeds_warm_pool_then_warm_scale_out(qwen):
    """Scale-in drains a TE's device-resident params back to host DRAM
    (RELEASED → warm leg); the next ``scale_to`` brings the remainder up
    from the WarmPool instead of cold, with serving parity."""
    bundle, params = qwen
    pool = WarmPool()
    asset = bundle.cfg.name
    je = _plane(bundle, params, TopologySpec(pd=0, colo=2),
                policy="round_robin", warm_pool=pool)
    je.submit(_prompts(1)[0], sampling=SP)
    je.run_to_completion()
    je.drain("te-colo1")
    je.run_to_completion()
    assert pool.hit(asset), "released params must land in the warm pool"
    assert je.n_serving() == 1
    # deficit 2 > 1 fork source: one round = 1 fork + 1 DRAM-warm bring-up
    plan = je.scale_to(3)
    assert len(plan["rounds"]) == 1
    assert plan["tiers"] == {"fork": 1, "warm": 1, "cold": 0}
    assert pool.hits >= 1
    prompts = _prompts(4, seed0=30)
    from repro.core.scheduling import round_robin_scheduler
    je._rr = round_robin_scheduler(je._handles)
    rids = [je.submit(list(p), sampling=SP) for p in prompts]
    comps = {c.req_id: c.tokens for c in je.run_to_completion()}
    assert [comps[r] for r in rids] == _reference_tokens(bundle, params,
                                                         prompts)
    je.close()


@pytest.mark.slow
@pytest.mark.fleet
def test_drain_cancel_on_load_resurgence(qwen):
    """A load resurgence while a TE drains legally walks it DRAINING →
    SERVING (drain-cancel) instead of releasing capacity the fleet is
    about to need; admissions resume and parity holds."""
    bundle, params = qwen
    trig = DrainTrigger(low_watermark=0.5, patience=100,
                        resurge_factor=1.0)
    je = _plane(bundle, params, TopologySpec(pd=0, colo=2),
                policy="round_robin", drain_trigger=trig)
    victim = je.handles[1]
    je.drain(victim.te_id)
    assert not victim.admitting
    # resurgence: the surviving TE's load shoots past factor*watermark
    prompts = _prompts(6, seed0=60)
    rids = [je.submit(list(p), sampling=SP) for p in prompts]
    je.step()
    assert victim.state is TEState.SERVING, "drain must have been cancelled"
    assert victim.admitting
    kinds = [e["kind"] for e in je.scale_events]
    assert kinds[:2] == ["drain", "drain_cancel"]
    assert "release" not in kinds
    comps = {c.req_id: c.tokens for c in je.run_to_completion()}
    assert [comps[r] for r in rids] == _reference_tokens(bundle, params,
                                                         prompts)
    assert [h.te_id for h in je.handles] == ["te-colo0", "te-colo1"]
    je.close()


@pytest.mark.slow
@pytest.mark.fleet
def test_drain_resubmits_mid_prefill_to_destination(qwen):
    """Mid-PREFILL sequences on a draining TE re-enter the drain
    destination's scheduler from the prompt (token-level restart) instead
    of finishing prefill on a TE that's leaving — with greedy parity and
    the restart recorded in ``resubmits``, not ``scale_events``."""
    bundle, params = qwen
    prompts = _prompts(4, length=40, seed0=80)    # > chunk: multi-step prefill
    je = _plane(bundle, params, TopologySpec(pd=0, colo=2),
                policy="round_robin")
    rids = [je.submit(list(p), sampling=SP) for p in prompts]
    victim = je.handles[1]
    assert any(e.scheduler.queued_seqs() for e in [victim.engine]), \
        "victim must hold not-yet-prefilled work for the regression"
    je.drain(victim.te_id)
    je.step()                                     # pump: re-submission happens
    moved = {r["req_id"] for r in je.resubmits}
    assert moved, "queued prefills must have been re-submitted"
    assert all(r["from"] == "te-colo1" and r["to"] == "te-colo0"
               for r in je.resubmits)
    # the moved requests' serving tasks re-point at the destination while
    # still in flight (records pop on completion)
    for rid in moved:
        rec = je.requests[rid]
        assert any(t.te_id == "te-colo0" for t in rec.job.tasks)
    comps = {c.req_id: c.tokens for c in je.run_to_completion()}
    assert len(comps) == 4
    assert [comps[r] for r in rids] == _reference_tokens(bundle, params,
                                                         prompts)
    kinds = [e["kind"] for e in je.scale_events]
    assert kinds == ["drain", "release"]          # routing isn't fleet shape
    assert victim.state is TEState.RELEASED
    je.close()
