"""Docs-consistency checks (ISSUE-4 CI satellite).

The repo's convention (DESIGN.md preamble) is that ``DESIGN.md §N``
citations in ``src/`` docstrings/comments are load-bearing references;
these tests keep them from rotting: every cited section must exist, and
the README must document every benchmark key ``benchmarks/run.py`` knows.
"""
import re
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def test_design_section_citations_resolve():
    design = (ROOT / "DESIGN.md").read_text()
    sections = set(re.findall(r"^## §(\d+)", design, re.M))
    assert sections, "DESIGN.md lost its '## §N' section headers"
    unresolved = {}
    for py in sorted((ROOT / "src").rglob("*.py")):
        cited = set(re.findall(r"DESIGN\.md §(\d+)", py.read_text()))
        bad = cited - sections
        if bad:
            unresolved[str(py.relative_to(ROOT))] = sorted(bad)
    assert not unresolved, (
        f"DESIGN.md §-citations pointing at missing sections: {unresolved}")


def test_readme_documents_every_bench_key():
    readme = (ROOT / "README.md").read_text()
    harness = (ROOT / "benchmarks" / "run.py").read_text()
    keys = re.findall(r'^\s*\("([a-z0-9_]+)",\s*"benchmarks\.', harness,
                      re.M)
    assert keys, "benchmarks/run.py MODULES table not found"
    missing = [k for k in keys if f"`{k}`" not in readme]
    assert not missing, (
        f"README benchmark index is missing run.py keys: {missing}")


def test_readme_documents_every_make_target():
    readme = (ROOT / "README.md").read_text()
    makefile = (ROOT / "Makefile").read_text()
    targets = re.findall(r"^([a-z][a-z0-9-]*):.*##", makefile, re.M)
    assert targets, "Makefile lost its '## help' annotations"
    missing = [t for t in targets if f"make {t}" not in readme]
    assert not missing, f"README is missing make targets: {missing}"
