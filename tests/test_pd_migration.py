"""DistFlow v2 PD-migration tests (DESIGN.md §7).

Device-resident shard-aware KV migration between prefill and decode TEs:
for (P-tp, D-tp) ∈ {(1,1),(2,2),(4,2),(2,4)} a request prefilled on a P-TE
and migrated to a D-TE must produce bit-identical greedy tokens to the same
request served colocated; cross-tp pairs exercise the in-flight reshard
(jax.device_put onto the destination mesh's pool sharding) across DISJOINT
device windows. Also covered: overlapped (async) import, per-link ICI
pricing, the DistFlow clock/wall accounting fixes, SlotRunner recurrent-
state migration (rwkv6, recurrentgemma), and per-shard NPU-fork onto a
live SPMD TE.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine import EngineConfig, FlowServe, Request, SamplingParams
from repro.engine.distflow import BufferInfo, DistFlow
from repro.models import get_model

SP = SamplingParams(temperature=0.0, max_new_tokens=6, stop_on_eos=False)
PROMPT = [1] + [int(x) for x in np.random.RandomState(7).randint(3, 200, 14)]


def _needs(n):
    return pytest.mark.skipif(
        jax.device_count() < n,
        reason=f"needs >={n} devices "
               "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _ecfg(mode, tp, offset=0, **kw):
    return EngineConfig(mode=mode, tp=tp, device_offset=offset, n_pages=64,
                        page_size=8, n_slots=4, max_len=96,
                        max_batch_tokens=32, chunk_size=8, max_decode_batch=4,
                        **kw)


def _engine(bundle, params, mode="colocated", tp=1, offset=0, **kw):
    return FlowServe(bundle, params, _ecfg(mode, tp, offset, **kw),
                     name=f"te-{mode}-tp{tp}@{offset}")


@pytest.fixture(scope="module")
def qwen():
    bundle = get_model("qwen3-8b", smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    return bundle, params


def _colocated_tokens(bundle, params, prompts, tp=1):
    eng = _engine(bundle, params, "colocated", tp=tp)
    ids = [eng.add_request(Request(prompt_tokens=p, sampling=SP))
           for p in prompts]
    comps = {c.req_id: c.tokens for c in eng.run_to_completion()}
    return [comps[r] for r in ids]


def _pd_tokens(bundle, params, prompts, ptp, dtp, **migrate_kw):
    """Prefill on a P-TE, migrate over DistFlow, decode on a D-TE (on a
    disjoint device window when both are sharded)."""
    pe = _engine(bundle, params, "prefill", tp=ptp)
    de = _engine(bundle, params, "decode", tp=dtp,
                 offset=ptp if dtp > 1 and ptp + dtp <= jax.device_count()
                 else 0)
    pe.distflow.link_cluster([de.distflow])
    ids = [pe.add_request(Request(prompt_tokens=p, sampling=SP))
           for p in prompts]
    comps = {}
    for _ in range(2000):
        if not (pe.has_work() or de.has_work()) \
                and not pe._prefill_done_buffer:
            break
        pe.step()
        for rid in pe.pop_migratable():
            pe.migrate_out(rid, de, **migrate_kw)
        for c in de.step():
            comps[c.req_id] = c.tokens
    assert len(comps) == len(prompts)
    return [comps[r] for r in ids], pe, de


# ---------------------------------------------------------------------------
# Paged-path parity across the tp matrix (acceptance grid)
# ---------------------------------------------------------------------------


def test_pd_migration_tp1_to_tp1(qwen):
    bundle, params = qwen
    got, pe, de = _pd_tokens(bundle, params, [PROMPT], 1, 1)
    assert got == _colocated_tokens(bundle, params, [PROMPT], tp=1)
    assert pe.distflow.bytes_moved() > 0
    assert de.pool.full_pool_copies == 0          # donated scatter, no rewrite


@_needs(4)
def test_pd_migration_tp2_to_tp2(qwen):
    bundle, params = qwen
    got, pe, de = _pd_tokens(bundle, params, [PROMPT], 2, 2)
    assert got == _colocated_tokens(bundle, params, [PROMPT], tp=2)
    assert pe.distflow.log[-1].links == 2         # bytes/tp per parallel link
    assert de.pool.full_pool_copies == 0


@_needs(6)
@pytest.mark.slow
def test_pd_migration_tp4_to_tp2_reshards(qwen):
    bundle, params = qwen
    prompts = [PROMPT, [1] + list(range(40, 52))]     # multi-request migration
    got, pe, de = _pd_tokens(bundle, params, prompts, 4, 2)
    assert got == _colocated_tokens(bundle, params, prompts, tp=2)
    # destination pool is genuinely sharded on the D mesh (disjoint window)
    assert de.pool.k.sharding.spec == de.pool.sharding.spec
    assert de.pool.full_pool_copies == 0


@_needs(6)
@pytest.mark.slow
def test_pd_migration_tp2_to_tp4_reshards(qwen):
    bundle, params = qwen
    got, pe, de = _pd_tokens(bundle, params, [PROMPT], 2, 4)
    assert got == _colocated_tokens(bundle, params, [PROMPT], tp=4)
    assert de.pool.full_pool_copies == 0


@pytest.mark.slow
def test_host_gather_flag_keeps_v1_path(qwen):
    """The old host round-trip stays available behind a flag and still
    serves correctly — it is the benchmark baseline."""
    bundle, params = qwen
    got, pe, de = _pd_tokens(bundle, params, [PROMPT], 1, 1, host_gather=True)
    assert got == _colocated_tokens(bundle, params, [PROMPT], tp=1)
    assert de.pool.full_pool_copies == 2          # k and v each rewritten


# ---------------------------------------------------------------------------
# Device-resident export / overlapped import semantics
# ---------------------------------------------------------------------------


@_needs(2)
def test_export_is_device_resident_and_sharded(qwen):
    bundle, params = qwen
    pe = _engine(bundle, params, "prefill", tp=2)
    rid = pe.add_request(Request(prompt_tokens=PROMPT, sampling=SP))
    while pe.has_work():
        pe.step()
    payload = pe.export_kv(rid)
    assert isinstance(payload["k"], jax.Array)    # no np.asarray in export
    assert "model" in [a for e in payload["k"].sharding.spec if e
                       for a in (e if isinstance(e, tuple) else (e,))]


@pytest.mark.slow
def test_overlap_defers_import_until_first_decode(qwen):
    """Async migration: the D-TE holds a MigrationHandle and keeps stepping;
    the pool scatter happens at the first decode of the migrated seq."""
    bundle, params = qwen
    pe = _engine(bundle, params, "prefill")
    de = _engine(bundle, params, "decode")
    pe.distflow.link_cluster([de.distflow])
    rid = pe.add_request(Request(prompt_tokens=PROMPT, sampling=SP))
    while pe.has_work():
        pe.step()
    assert pe.pop_migratable() == [rid]
    pe.migrate_out(rid, de, overlap=True)
    handle = de._seqs[rid].extra["_kv_pending"]
    assert not handle.xfer.done                   # still in flight
    comps = de.run_to_completion()
    assert handle.xfer.done                       # waited at first decode
    assert [c.tokens for c in comps] == \
        _colocated_tokens(bundle, params, [PROMPT])


def test_per_layer_ready_events(qwen):
    """ROADMAP PR-2 follow-up: MigrationHandle exposes per-layer chunk
    readiness, and the engine scatters each chunk as IT lands — the first
    decode of a migrated sequence starts behind the FIRST chunk, not the
    last (FlowServe._import_layerwise)."""
    bundle, params = qwen
    pe = _engine(bundle, params, "prefill")
    rid = pe.add_request(Request(prompt_tokens=PROMPT, sampling=SP))
    while pe.has_work():
        pe.step()
    payload = pe.export_kv(rid)
    handle = pe.distflow.transfer_sharded(
        {"k": payload["k"], "v": payload["v"]}, "nowhere", layer_chunks=2)
    assert handle.landed == [False, False]
    l0, k0, _ = handle.wait_chunk(0)
    assert l0 == 0 and handle.landed == [True, False]
    assert not handle.xfer.done           # tail chunk still outstanding
    assert handle.chunk_ready(1)          # device_put long since landed
    handle.wait_chunk(1)
    assert handle.xfer.done               # last consumed -> transfer done
    np.testing.assert_array_equal(np.asarray(k0),
                                  np.asarray(payload["k"])[:k0.shape[0]])


def test_layer_chunked_transfer_covers_all_layers(qwen):
    bundle, params = qwen
    pe = _engine(bundle, params, "prefill")
    rid = pe.add_request(Request(prompt_tokens=PROMPT, sampling=SP))
    while pe.has_work():
        pe.step()
    payload = pe.export_kv(rid)
    n_layers = payload["k"].shape[0]
    handle = pe.distflow.transfer_sharded(
        {"k": payload["k"], "v": payload["v"]}, "nowhere", layer_chunks=2)
    chunks = handle.wait()["chunks"]
    assert len(chunks) == min(2, n_layers)
    assert sum(c[1].shape[0] for c in chunks) == n_layers
    got = np.concatenate([np.asarray(c[1]) for c in chunks], axis=0)
    np.testing.assert_array_equal(got, np.asarray(payload["k"]))


# ---------------------------------------------------------------------------
# DistFlow accounting (clock + wall satellites, per-link pricing)
# ---------------------------------------------------------------------------


def test_transfer_charges_both_endpoints():
    a, b = DistFlow("a"), DistFlow("b")
    a.link_cluster([b])
    a.transfer(BufferInfo("a", "npu", payload=np.zeros(1 << 16, np.uint8)),
               BufferInfo("b", "npu", deliver=lambda p: None))
    assert a.sim_clock > 0
    assert b.sim_clock == a.sim_clock             # the peer observed it too


def test_broadcast_records_wall_and_charges_peers():
    src = DistFlow("src")
    dsts = [DistFlow(f"d{i}") for i in range(3)]
    src.link_cluster(dsts)
    sink = []
    xfers = src.broadcast(
        BufferInfo("src", "npu", payload=np.zeros(1 << 20, np.uint8)),
        [BufferInfo(d.owner, "npu", deliver=lambda p: sink.append(p.copy()))
         for d in dsts])
    assert all(x.wall_seconds > 0 for x in xfers)  # real wall time recorded
    assert all(x.sim_seconds > 0 for x in xfers)
    for d in dsts:
        assert d.sim_clock == pytest.approx(xfers[0].sim_seconds)
    assert src.bytes_moved() == 3 * (1 << 20)      # broadcasts are logged


def test_sharded_transfer_prices_bytes_per_link():
    a, b = DistFlow("a"), DistFlow("b")
    a.link_cluster([b])
    kv = {"k": jnp.zeros((4, 8, 8, 4, 8)), "v": jnp.zeros((4, 8, 8, 4, 8))}
    one = a.transfer_sharded(kv, "b", src_tp=1, dst_tp=1, layer_chunks=1)
    four = a.transfer_sharded(kv, "b", src_tp=4, dst_tp=4, layer_chunks=1)
    cross = a.transfer_sharded(kv, "b", src_tp=4, dst_tp=2, layer_chunks=1)
    lat = 1e-6                                     # ici latency term
    assert four.xfer.sim_seconds - lat == \
        pytest.approx((one.xfer.sim_seconds - lat) / 4)
    assert cross.xfer.links == 2                   # min(src_tp, dst_tp)
    assert b.sim_clock == pytest.approx(a.sim_clock)


# ---------------------------------------------------------------------------
# SlotRunner (recurrent-state) migration — rwkv6 / recurrentgemma
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "recurrentgemma-2b"])
def test_slot_migration_matches_colocated(arch):
    bundle = get_model(arch, smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    prompts = [PROMPT, [1] + list(range(30, 43))]
    got, pe, de = _pd_tokens(bundle, params, prompts, 1, 1)
    assert got == _colocated_tokens(bundle, params, prompts)
    assert pe.distflow.bytes_moved() > 0          # state went over DistFlow


# ---------------------------------------------------------------------------
# NPU-fork onto a live SPMD TE (acceptance: shard-for-shard params)
# ---------------------------------------------------------------------------


@_needs(6)
@pytest.mark.slow
def test_npu_fork_onto_tp2_te_shard_for_shard(qwen):
    bundle, params = qwen
    src = _engine(bundle, params, "colocated", tp=2)
    fork = FlowServe.fork_from(src, _ecfg("colocated", 2, offset=4),
                               name="te-forked")
    # params match the source shard-for-shard: every leaf's value is equal
    # and every addressable shard holds exactly its slice of the full array
    for a, b in zip(jax.tree.leaves(src.runner.params),
                    jax.tree.leaves(fork.runner.params)):
        full = np.asarray(a)
        np.testing.assert_array_equal(full, np.asarray(b))
        for shard in b.addressable_shards:
            np.testing.assert_array_equal(np.asarray(shard.data),
                                          full[shard.index])
    # destination shards live on the fork's OWN device window (offset 4)
    wq = fork.runner.params["blocks"]["attn"]["wq"]
    assert {d.id for d in wq.sharding.device_set} == {4, 5}
    # both endpoints observed the fork; the transfer is on the source log
    assert src.distflow.sim_clock > 0
    assert fork.distflow.sim_clock == src.distflow.sim_clock
    assert src.distflow.log[-1].links == 2
    # the forked TE serves identically without any re-initialization
    rid = fork.add_request(Request(prompt_tokens=PROMPT, sampling=SP))
    comps = {c.req_id: c.tokens for c in fork.run_to_completion()}
    assert comps[rid] == _colocated_tokens(bundle, params, [PROMPT], tp=2)[0]


def test_npu_fork_live_dcn_fallback_slower(qwen):
    bundle, params = qwen
    from repro.core.scaling import npu_fork_live
    _, ici = npu_fork_live(params, bundle.cfg, None, source=DistFlow("s1"))
    _, dcn = npu_fork_live(params, bundle.cfg, None, source=DistFlow("s2"),
                           link="dcn")
    assert dcn.seconds > ici.seconds
    assert ici.path == "npu_fork_ici" and dcn.path == "npu_fork_dcn"
