"""Batched ragged prefill + microkernel runner registry tests (DESIGN.md §12).

The one-dispatch prefill path — flat ragged token stream, per-token
(page, slot, position) indices, one KV scatter per layer across all
sequences, chunk-final logits with first-token sampling fused in — must be
bit-identical to the legacy per-sequence path on greedy decoding, across
ragged prompt mixes, qwen3 + granite (MoE), and TP ∈ {1,2}. Steady-state
serving must cost ONE prefill dispatch per step and ZERO prefill jit
compiles after ``warmup_prefill``. The slot family's riders — pow2-bucketed
masked-tail prefill and fused decode+sample — get the same parity
treatment, and the registry must resolve families from ``ModelConfig``
instead of the engine special-casing runner classes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine import EngineConfig, FlowServe, Request, SamplingParams
from repro.engine.runners import (RunnerFamily, families, pick_runner,
                                  register_family, resolve_family)
from repro.models import get_model

needs2 = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >=2 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)")

SP = SamplingParams(temperature=0.0, max_new_tokens=8, stop_on_eos=False)


@pytest.fixture(scope="module")
def qwen():
    bundle = get_model("qwen3-8b", smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    return bundle, params


@pytest.fixture(scope="module")
def granite():
    bundle = get_model("granite-moe-3b-a800m", smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    return bundle, params


@pytest.fixture(scope="module")
def rwkv():
    bundle = get_model("rwkv6-1.6b", smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    return bundle, params


@pytest.fixture(scope="module")
def rgemma():
    bundle = get_model("recurrentgemma-2b", smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    return bundle, params


def _prompts(n, length=11, seed0=0):
    return [[1] + [int(x) for x in
                   np.random.RandomState(seed0 + i).randint(3, 200, length)]
            for i in range(n)]

# ragged mix: 1-token prompt (vacuous prefill), tiny, exactly one chunk,
# chunk-boundary+1 (the extension token rides a 1-token final chunk), long
RAGGED = [[7], [5, 6, 9], list(range(3, 11)), list(range(3, 12)),
          [1] + [int(x) for x in np.random.RandomState(3).randint(3, 200, 21)]]


def _serve(model, prompts, sp=SP, tp=1, **kw):
    bundle, params = model
    ecfg = EngineConfig(tp=tp, n_pages=64, page_size=8, max_batch_tokens=32,
                        chunk_size=8, max_decode_batch=4, **kw)
    te = FlowServe(bundle, params, ecfg)
    for i, p in enumerate(prompts):
        te.add_request(Request(prompt_tokens=p, sampling=sp, req_id=f"r{i}"))
    comps = {c.req_id: c.tokens for c in te.run_to_completion()}
    assert len(comps) == len(prompts)
    return [comps[f"r{i}"] for i in range(len(prompts))], te


# ---------------------------------------------------------------------------
# Greedy parity: batched ragged prefill vs the legacy per-sequence path
# ---------------------------------------------------------------------------


def test_batched_parity_qwen3(qwen):
    want, te0 = _serve(qwen, _prompts(4), batched_prefill=False)
    got, te = _serve(qwen, _prompts(4), batched_prefill=True)
    assert got == want
    # the whole point: fewer prefill dispatches for the same tokens
    assert te.prefill_dispatches < te0.prefill_dispatches


def test_batched_parity_ragged_mix(qwen):
    want, _ = _serve(qwen, RAGGED, batched_prefill=False)
    got, _ = _serve(qwen, RAGGED, batched_prefill=True)
    assert got == want


def test_batched_parity_granite(granite):
    want, _ = _serve(granite, RAGGED[:4], batched_prefill=False)
    got, _ = _serve(granite, RAGGED[:4], batched_prefill=True)
    assert got == want


@needs2
def test_batched_parity_qwen3_tp2(qwen):
    want, _ = _serve(qwen, _prompts(3), tp=2, batched_prefill=False)
    got, _ = _serve(qwen, _prompts(3), tp=2, batched_prefill=True)
    assert got == want


@needs2
@pytest.mark.slow
def test_batched_parity_granite_tp2(granite):
    want, _ = _serve(granite, _prompts(3), tp=2, batched_prefill=False)
    got, _ = _serve(granite, _prompts(3), tp=2, batched_prefill=True)
    assert got == want


def test_batched_stochastic_serves_valid_tokens(qwen):
    sp = SamplingParams(temperature=0.9, top_p=0.9, max_new_tokens=6,
                        stop_on_eos=False)
    got, _ = _serve(qwen, _prompts(3), sp=sp, batched_prefill=True)
    bundle, _ = qwen
    for toks in got:
        assert len(toks) == 6
        assert all(0 <= t < bundle.cfg.vocab_size for t in toks)


def test_first_token_sampled_in_dispatch(qwen):
    """A completing prompt leaves its ONE prefill dispatch with the first
    generated token: the engine fetched it through prefill_syncs (never
    the decode-path host_syncs, which §8's tests pin) and the sequence
    satisfies the decode invariant immediately."""
    bundle, params = qwen
    ecfg = EngineConfig(n_pages=64, page_size=8, max_batch_tokens=32,
                        chunk_size=8, max_decode_batch=4, batched_prefill=True)
    te = FlowServe(bundle, params, ecfg)
    te.add_request(Request(prompt_tokens=_prompts(1)[0], sampling=SP,
                           req_id="r0"))
    while not te.scheduler.running:
        te.step()
    seq = te._seqs["r0"]
    assert len(seq.tokens) == seq.n_prompt + 1    # first token appended
    assert seq.n_cached == len(seq.tokens) - 1    # decode invariant holds
    assert te.prefill_syncs >= 1


def test_max_new_tokens_one_finishes_in_prefill(qwen):
    """max_new_tokens=1: the extension row's sampled token IS the whole
    completion — the request finishes without a single decode step."""
    sp = SamplingParams(temperature=0.0, max_new_tokens=1, stop_on_eos=False)
    want, _ = _serve(qwen, _prompts(2), sp=sp, batched_prefill=False)
    got, te = _serve(qwen, _prompts(2), sp=sp, batched_prefill=True)
    assert got == want
    assert te.decode_steps == 0


# ---------------------------------------------------------------------------
# Steady-state regression: 1 prefill dispatch / step, 0 recompiles
# ---------------------------------------------------------------------------


def test_one_prefill_dispatch_per_step(qwen):
    bundle, params = qwen
    ecfg = EngineConfig(n_pages=64, page_size=8, max_batch_tokens=32,
                        chunk_size=8, max_decode_batch=4, max_prefill_seqs=4,
                        batched_prefill=True)
    te = FlowServe(bundle, params, ecfg)
    for i, p in enumerate(RAGGED):
        te.add_request(Request(prompt_tokens=p, sampling=SP, req_id=f"r{i}"))
    while te.has_work():
        d0 = te.prefill_dispatches
        te.step()
        assert te.prefill_dispatches - d0 <= 1   # NEVER more than one


def test_warmup_prefill_precompiles_grid(qwen):
    bundle, params = qwen
    ecfg = EngineConfig(n_pages=64, page_size=8, max_batch_tokens=32,
                        chunk_size=8, max_decode_batch=4, max_prefill_seqs=4,
                        batched_prefill=True)
    te = FlowServe(bundle, params, ecfg)
    n = te.warmup_prefill(max_pages=8)
    # token buckets pow2s(32+4) = {1..64} = 7, page buckets pow2s(8) = 4
    assert n == 7 * 4
    compiles0 = te.prefill_jit_compiles
    for i, p in enumerate(RAGGED):
        te.add_request(Request(prompt_tokens=p, sampling=SP, req_id=f"r{i}"))
    comps = te.run_to_completion()
    assert len(comps) == len(RAGGED)
    assert te.prefill_jit_compiles == compiles0   # serving never compiled


def test_legacy_flag_keeps_per_seq_path(qwen):
    _, te = _serve(qwen, _prompts(3), batched_prefill=False)
    assert te.prefill_syncs == 0          # batched-path counter stays silent
    assert not te.runner.prefill._ragged_fns


# ---------------------------------------------------------------------------
# Slot family riders: bucketed masked-tail prefill + fused decode/sample
# ---------------------------------------------------------------------------


def _serve_slot(model, prompts, bucket, fused, sp=SP):
    bundle, params = model
    ecfg = EngineConfig(n_slots=4, max_len=64, max_batch_tokens=32,
                        chunk_size=8, max_decode_batch=4, fused_decode=fused)
    te = FlowServe(bundle, params, ecfg)
    te.runner.bucket_prefill = bucket
    for i, p in enumerate(prompts):
        te.add_request(Request(prompt_tokens=p, sampling=sp, req_id=f"r{i}"))
    comps = {c.req_id: c.tokens for c in te.run_to_completion()}
    assert len(comps) == len(prompts)
    return [comps[f"r{i}"] for i in range(len(prompts))], te


@pytest.mark.parametrize("model_fx", ["rwkv", "rgemma"])
def test_slot_bucketed_prefill_parity(model_fx, request):
    model = request.getfixturevalue(model_fx)
    want, te0 = _serve_slot(model, RAGGED[:4], bucket=False, fused=False)
    got, te = _serve_slot(model, RAGGED[:4], bucket=True, fused=False)
    assert got == want
    # bucketing shares executables across ragged chunk lengths
    assert te.prefill_jit_compiles < te0.prefill_jit_compiles


@pytest.mark.parametrize("model_fx", ["rwkv", "rgemma"])
def test_slot_fused_sampling_parity(model_fx, request):
    model = request.getfixturevalue(model_fx)
    want, te0 = _serve_slot(model, _prompts(3), bucket=True, fused=False)
    got, te = _serve_slot(model, _prompts(3), bucket=True, fused=True)
    assert got == want
    assert te.sampler_dispatches == 0     # sampling fused into the step
    assert te.host_dispatches < te0.host_dispatches


def test_slot_fused_stochastic_valid(rwkv):
    sp = SamplingParams(temperature=0.8, top_p=0.9, max_new_tokens=5,
                        stop_on_eos=False)
    got, _ = _serve_slot(rwkv, _prompts(2), bucket=True, fused=True, sp=sp)
    bundle, _ = rwkv
    for toks in got:
        assert len(toks) == 5
        assert all(0 <= t < bundle.cfg.vocab_size for t in toks)


# ---------------------------------------------------------------------------
# Runner registry: families resolved from ModelConfig, not engine if-ladders
# ---------------------------------------------------------------------------


def test_registry_resolution(qwen, rwkv):
    assert resolve_family(qwen[0].cfg).name == "paged"
    assert resolve_family(rwkv[0].cfg).name == "slot"
    assert pick_runner(qwen[0].cfg) == "paged"
    assert pick_runner(rwkv[0].cfg) == "slot"
    names = [f.name for f in families()]
    assert names.index("paged") < names.index("slot")   # ordered match


def test_registry_engine_uses_family(qwen, rwkv):
    bundle, params = qwen
    te = FlowServe(bundle, params, EngineConfig(n_pages=16, page_size=8))
    assert te.family.uses_pages and te.pool is not None
    bundle, params = rwkv
    te = FlowServe(bundle, params, EngineConfig(n_slots=2, max_len=32))
    assert not te.family.uses_pages and te.pool is None


def test_registry_custom_family_overrides():
    from repro.engine.runners import SlotRunner
    probe = RunnerFamily(name="probe", runner_cls=SlotRunner,
                         matches=lambda cfg: getattr(cfg, "name", "") == "?",
                         uses_pages=False)
    before = [f.name for f in families()]
    register_family(probe)
    try:
        assert "probe" in [f.name for f in families()]
        # re-registering the same name replaces in place, not duplicates
        register_family(probe)
        assert [f.name for f in families()].count("probe") == 1
    finally:
        import repro.engine.runners.base as B
        B._FAMILIES[:] = [f for f in B._FAMILIES if f.name != "probe"]
    assert [f.name for f in families()] == before
