"""Live serving plane tests (DESIGN.md §9).

The ServingJobEngine composes real FLOWSERVE TEs — PD-disaggregated pairs
handing KV over DistFlow plus PD-colocated engines — under Algorithm-1
placement fed by REAL load signals. Multi-TE tests (several live engines
per test) are marked slow; the fast subset keeps the single-engine and
pure-python coverage.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scaling import DRAMPageCache, FastScaler, LoadSpreadTrigger
from repro.core.serving_plane import ServingJobEngine, TopologySpec
from repro.engine import EngineConfig, FlowServe, Request, SamplingParams
from repro.models import get_model

SP = SamplingParams(temperature=0.0, max_new_tokens=6, stop_on_eos=False)
LENS, RATIOS = [16, 64], [0.25, 1.0]
PD_HEAT = np.ones((2, 2))            # every cell: disaggregate
COLO_HEAT = -np.ones((2, 2))         # every cell: colocate


def _ecfg(**kw):
    base = dict(n_pages=64, page_size=8, max_batch_tokens=32,
                chunk_size=8, max_decode_batch=4)
    base.update(kw)
    return EngineConfig(**base)


def _plane(bundle, params, topo, heat=PD_HEAT, **kw):
    return ServingJobEngine(bundle, params, topo, heatmap=heat,
                            prefill_lens=LENS, decode_ratios=RATIOS,
                            ecfg=_ecfg(), **kw)


def _prompts(n, length=14, seed0=0):
    return [[1] + [int(x) for x in
                   np.random.RandomState(seed0 + i).randint(3, 200, length)]
            for i in range(n)]


@pytest.fixture(scope="module")
def qwen():
    bundle = get_model("qwen3-8b", smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    return bundle, params


# ---------------------------------------------------------------------------
# Fast: pure-python plane pieces
# ---------------------------------------------------------------------------


def test_topology_parse():
    t = TopologySpec.parse("pd=2,colo=2")
    assert (t.pd, t.colo, t.tp) == (2, 2, 1) and t.n_engines() == 6
    assert TopologySpec.parse("pd=1,colo=1,tp=2").tp == 2
    with pytest.raises(ValueError):
        TopologySpec.parse("pd=0,colo=0")
    with pytest.raises(ValueError):
        TopologySpec.parse("pp=3")


def test_load_spread_trigger_semantics():
    trig = LoadSpreadTrigger(threshold=0.5, patience=3, min_load=1.0,
                             max_fires=5)
    # near-idle fleets never trigger regardless of relative spread
    assert not trig.observe([0.1, 0.0])
    # sustained breach fires exactly at patience...
    assert not trig.observe([10.0, 1.0])
    assert not trig.observe([10.0, 1.0])
    assert trig.observe([10.0, 1.0])
    # ...then stays disarmed while the breach persists (the forked TE joins
    # with zero load, keeping the spread high — no fork storm)
    for _ in range(10):
        assert not trig.observe([10.0, 1.0, 0.0])
    # recovery re-arms; the next sustained breach fires again
    assert not trig.observe([5.0, 5.0])
    assert trig.armed
    for _ in range(2):
        assert not trig.observe([10.0, 0.0])
    assert trig.observe([10.0, 0.0])
    assert trig.fires == 2


def test_load_spread_trigger_max_fires():
    trig = LoadSpreadTrigger(threshold=0.5, patience=1, min_load=1.0,
                             max_fires=1)
    assert trig.observe([10.0, 1.0])
    assert not trig.observe([5.0, 5.0])      # re-armed...
    assert not trig.observe([10.0, 1.0])     # ...but capped
    assert trig.fires == 1


# ---------------------------------------------------------------------------
# Single-engine: live load signal
# ---------------------------------------------------------------------------


def test_live_load_metrics_and_handle_refresh(qwen):
    bundle, params = qwen
    te = FlowServe(bundle, params, _ecfg(), name="te-live")
    prompt = _prompts(1, length=20)[0]
    te.add_request(Request(prompt_tokens=prompt, sampling=SP))
    m = te.load_metrics()
    # queued prefill owes every prompt token but the last; nothing decoded
    assert m["queued_prefill_tokens"] == len(prompt) - 1
    assert m["inflight_decode_tokens"] == SP.max_new_tokens
    assert m["n_queued"] == 1 and m["n_running"] == 0

    from repro.core.scheduling import TEHandle
    handle = TEHandle("te-live", "colocated", engine=te)
    load0 = handle.refresh()
    assert load0 == pytest.approx(len(prompt) - 1 + SP.max_new_tokens)
    comps = te.run_to_completion()
    assert len(comps) == 1
    assert handle.refresh() == 0.0           # drained fleet reads zero
    # stub handles (no engine) keep their hand-fed load under refresh
    stub = TEHandle("sim", "colocated", load=123.0)
    assert stub.refresh() == 123.0 and stub.load == 123.0


# ---------------------------------------------------------------------------
# Multi-TE (slow): handoff parity, Algorithm-1 counters, scaling, RR
# ---------------------------------------------------------------------------


def _reference_tokens(bundle, params, prompts):
    ref = FlowServe(bundle, params, _ecfg(), name="ref")
    ids = [ref.add_request(Request(prompt_tokens=p, sampling=SP))
           for p in prompts]
    comps = {c.req_id: c.tokens for c in ref.run_to_completion()}
    return [comps[i] for i in ids]


@pytest.mark.slow
def test_pd_pair_handoff_parity_vs_colocated(qwen):
    """A request served through the plane's PD-pair steady path (prefill
    TE → DistFlow migrate → decode TE) yields bit-identical greedy tokens
    to the same request on a single colocated TE."""
    bundle, params = qwen
    prompts = _prompts(3)
    je = _plane(bundle, params, TopologySpec(pd=1, colo=0))
    rids = [je.submit(p, sampling=SP) for p in prompts]
    comps = {c.req_id: c.tokens for c in je.run_to_completion()}
    assert len(comps) == 3
    assert [comps[r] for r in rids] == _reference_tokens(bundle, params,
                                                         prompts)
    # request-job-task bookkeeping (§3): prefill + decode tasks both DONE
    for job in je.jobs.values():
        kinds = {t.kind.value: t.status.value for t in job.tasks}
        assert kinds == {"prefill": "done", "decode": "done"}
        assert job.status.value == "done"
    # the pair's engines actually split the phases
    pe, de = je.engines[0], je.engines[1]
    assert pe.distflow.bytes_moved() > 0     # KV really crossed DistFlow
    assert de.decode_steps > 0 and pe.decode_steps == 0


@pytest.mark.slow
def test_algorithm1_counters_under_skewed_heatmaps(qwen):
    bundle, params = qwen
    prompts = _prompts(4)
    # all-positive heatmap: every placement must be PD-disaggregated
    je = _plane(bundle, params, TopologySpec(pd=1, colo=1), heat=PD_HEAT)
    for p in prompts:
        je.submit(p, sampling=SP)
    assert len(je.run_to_completion()) == 4
    assert je.scheduler.decisions["pd_disagg"] == 4
    assert je.scheduler.decisions["pd_colo"] == 0
    colo = je.engines[-1]
    assert colo.steps == 0                   # colocated TE never touched

    # all-negative heatmap: every placement must be PD-colocated
    je2 = _plane(bundle, params, TopologySpec(pd=1, colo=1), heat=COLO_HEAT)
    for p in prompts:
        je2.submit(p, sampling=SP)
    assert len(je2.run_to_completion()) == 4
    assert je2.scheduler.decisions["pd_colo"] == 4
    assert je2.scheduler.decisions["pd_disagg"] == 0
    assert je2.engines[0].steps == 0         # prefill TE never touched


@pytest.mark.slow
def test_load_spread_fires_fastscaler_exactly_once(qwen):
    bundle, params = qwen
    scaler = FastScaler(DRAMPageCache())
    trig = LoadSpreadTrigger(threshold=0.5, patience=2, min_load=4.0,
                             max_fires=5)
    je = _plane(bundle, params, TopologySpec(pd=0, colo=2),
                policy="round_robin", scaler=scaler, trigger=trig)
    # round-robin alternates TEs; alternating huge/tiny prompts skews load
    for i in range(6):
        je.submit(_prompts(1, length=100 if i % 2 == 0 else 6, seed0=i)[0],
                  sampling=SP)
    comps = je.run_to_completion()
    assert len(comps) == 6
    # sustained breach fired once; the forked TE's zero load keeps the
    # spread high but the disarmed trigger must NOT fork again
    assert trig.fires == 1
    assert len(je.scale_events) == 1 and len(scaler.events) == 1
    assert scaler.events[0].path == "npu_fork_ici"
    assert [h.te_id for h in je.handles][-1] == "te-scale0"
    assert je.scheduler.tes["te-scale0"].engine is je.engines[-1]


@pytest.mark.slow
def test_migration_evicts_cached_prefixes_under_pressure(qwen):
    """A decode TE whose free list has been consumed by preserved prefix
    pages (completions release with keep_cached=True) must still admit
    migrations: import allocates through the RTC, which evicts zero-ref
    cached pages coherently — instead of OutOfPagesError crashing the
    plane's PD pump mid-handoff."""
    bundle, params = qwen
    pe = FlowServe(bundle, params, _ecfg(mode="prefill"), name="p")
    de = FlowServe(bundle, params,
                   _ecfg(mode="decode", n_pages=10), name="d")
    pe.distflow.link_cluster([de.distflow])
    for i in range(6):       # 6 requests x 3 pages >> 10-page pool
        prompt = _prompts(1, length=17, seed0=100 + i)[0]
        pe.add_request(Request(prompt_tokens=prompt, sampling=SP))
        for _ in range(200):
            pe.step()
            rids = pe.pop_migratable()
            if rids:
                pe.migrate_out(rids[0], de)
                break
        comps = de.run_to_completion()
        assert len(comps) == 1, f"request {i} lost under cache pressure"
    # the pool really was under prefix-cache pressure at some point
    assert de.rtc.stats["evictions"] > 0


@pytest.mark.slow
def test_round_robin_is_degenerate_policy(qwen):
    """round_robin_scheduler still drives the same live fleet: requests
    complete with reference tokens and Algorithm 1 never runs."""
    bundle, params = qwen
    prompts = _prompts(4)
    je = _plane(bundle, params, TopologySpec(pd=1, colo=1),
                policy="round_robin")
    rids = [je.submit(p, sampling=SP) for p in prompts]
    comps = {c.req_id: c.tokens for c in je.run_to_completion()}
    assert len(comps) == 4
    assert [comps[r] for r in rids] == _reference_tokens(bundle, params,
                                                         prompts)
    assert all(v == 0 for v in je.scheduler.decisions.values())
    # alternation hit both the pair and the colocated TE
    assert je.engines[0].steps > 0 and je.engines[-1].steps > 0
