"""SPMD tensor-parallel engine tests (DESIGN.md §5).

A TP>1 FLOWSERVE TE spans a 1×tp ("data","model") mesh of simulated host
devices (tests/conftest.py forces 8). It must reproduce the TP=1 engine:
greedy tokens bit-for-bit end-to-end, and raw decode/prefill logits within
fp32 tolerance. Two sharding regimes are covered:

  * qwen3-8b smoke at tp=2 — heads divide: attention + KV pool shard.
  * granite smoke at tp=4 — KV heads (2) do NOT divide: attention and the
    paged pool replicate, only MoE FFN / vocab shard.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine import EngineConfig, FlowServe, Request, SamplingParams
from repro.engine.kv_cache import pages_needed
from repro.engine.model_runner import SequenceState
from repro.engine.sampling import SamplingParams as SParams
from repro.engine.sampling import sample, sample_batch
from repro.launch.sharding import attn_shardable
from repro.models import get_model

needs2 = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >=2 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)")
needs4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >=4 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)")

SP = SamplingParams(temperature=0.0, max_new_tokens=6, stop_on_eos=False)
PROMPT = [1, 5, 9, 200, 41, 33, 77, 150, 3, 8, 12, 99]


def _mesh_axes(array) -> list:
    """Flat list of mesh-axis names an array's sharding spec mentions."""
    out = []
    for entry in tuple(array.sharding.spec):
        if entry is None:
            continue
        out.extend(entry if isinstance(entry, tuple) else (entry,))
    return out


def _engine(arch, tp, **kw):
    bundle = get_model(arch, smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    ecfg = EngineConfig(tp=tp, n_pages=64, page_size=8, n_slots=4, max_len=96,
                        max_batch_tokens=32, chunk_size=8, max_decode_batch=4,
                        **kw)
    return FlowServe(bundle, params, ecfg)


def _raw_logits(arch, tp):
    """(prefill-final, first-decode) logits straight off the PagedRunner."""
    te = _engine(arch, tp, enable_prefix_cache=False)
    seq = SequenceState("s0", tokens=list(PROMPT), n_prompt=len(PROMPT))
    seq.pages = te.pool.alloc(pages_needed(len(PROMPT) + 1, te.pool.page_size))
    pre = np.asarray(te.runner.prefill_chunk(seq, list(PROMPT)))
    seq.tokens.append(17)
    dec = np.asarray(te.runner.decode([seq])[0])
    return pre, dec


def _serve_tokens(arch, tp, n=3, **kw):
    te = _engine(arch, tp, **kw)
    prompts = [[1] + [int(x) for x in np.random.RandomState(i).randint(3, 200, 11)]
               for i in range(n)]
    for i, p in enumerate(prompts):
        te.add_request(Request(prompt_tokens=p, sampling=SP, req_id=f"r{i}"))
    comps = {c.req_id: c.tokens for c in te.run_to_completion()}
    assert len(comps) == n
    return [comps[f"r{i}"] for i in range(n)], te


# ---------------------------------------------------------------------------
# qwen3 smoke (heads divide → attention + KV pool shard)
# ---------------------------------------------------------------------------


@needs2
def test_tp2_decode_logits_match_tp1_qwen3():
    p1, d1 = _raw_logits("qwen3-8b", 1)
    p2, d2 = _raw_logits("qwen3-8b", 2)
    np.testing.assert_allclose(p1, p2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(d1, d2, rtol=1e-4, atol=1e-4)


@needs2
def test_tp2_qwen3_shards_attention_and_pool():
    te = _engine("qwen3-8b", 2)
    assert attn_shardable(te.cfg, 2)
    wq = te.runner.params["blocks"]["attn"]["wq"]
    assert "model" in _mesh_axes(wq)
    assert "model" in _mesh_axes(te.pool.k)


@needs2
def test_tp2_engine_tokens_equal_tp1_qwen3():
    t1, _ = _serve_tokens("qwen3-8b", 1)
    t2, te2 = _serve_tokens("qwen3-8b", 2)
    assert t1 == t2
    # fused decode (DESIGN.md §8): sampling rides inside the decode jit, so
    # ZERO standalone sampler dispatches; the legacy path still pays one
    # batched dispatch per step (and the old per-seq loop paid B)
    assert te2.sampler_dispatches == 0
    t2l, te2l = _serve_tokens("qwen3-8b", 2, fused_decode=False)
    assert t1 == t2l
    assert te2l.sampler_dispatches == te2l.decode_steps


@needs2
@pytest.mark.slow
def test_tp2_engine_tokens_equal_tp1_slotrunner():
    """SlotRunner family (recurrentgemma hybrid): seq-sharded dense caches."""
    t1, _ = _serve_tokens("recurrentgemma-2b", 1, n=2)
    t2, _ = _serve_tokens("recurrentgemma-2b", 2, n=2)
    assert t1 == t2


# ---------------------------------------------------------------------------
# granite smoke at tp=4 (KV heads do not divide → attention replicates,
# only MoE FFN / vocab shard)
# ---------------------------------------------------------------------------


@needs4
def test_tp4_granite_replicates_attention_shards_ffn():
    te = _engine("granite-moe-3b-a800m", 4)
    assert not attn_shardable(te.cfg, 4)      # 2 KV heads % 4 != 0
    wq = te.runner.params["blocks"]["attn"]["wq"]
    w_up = te.runner.params["blocks"]["moe"]["w_up"]
    assert "model" not in _mesh_axes(wq)
    assert "model" in _mesh_axes(w_up)
    assert "model" not in _mesh_axes(te.pool.k)


@needs4
def test_tp4_decode_logits_match_tp1_granite():
    p1, d1 = _raw_logits("granite-moe-3b-a800m", 1)
    p4, d4 = _raw_logits("granite-moe-3b-a800m", 4)
    np.testing.assert_allclose(p1, p4, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(d1, d4, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Batched sampling (TP-independent)
# ---------------------------------------------------------------------------


def test_sample_batch_greedy_matches_per_seq():
    key = jax.random.PRNGKey(3)
    logits = jax.random.normal(key, (5, 300)) * 4.0
    want = np.asarray([int(sample(logits[i:i + 1], SParams(temperature=0.0),
                                  jax.random.fold_in(key, i), 256)[0])
                       for i in range(5)])
    got = np.asarray(sample_batch(logits, np.zeros(5), np.ones(5),
                                  jax.random.PRNGKey(0), 256))
    np.testing.assert_array_equal(want, got)


def test_sample_batch_mixed_params_one_dispatch():
    key = jax.random.PRNGKey(7)
    logits = jax.random.normal(key, (4, 300)) * 4.0
    temps = np.asarray([0.0, 0.8, 0.0, 1.5], np.float32)
    top_ps = np.asarray([1.0, 0.9, 0.5, 1.0], np.float32)
    toks = np.asarray(sample_batch(logits, temps, top_ps,
                                   jax.random.PRNGKey(1), 256))
    assert toks.shape == (4,)
    assert (toks >= 0).all() and (toks < 256).all()   # pad vocab masked
    # greedy rows are deterministic regardless of the key
    greedy = np.argmax(np.where(np.arange(300)[None] >= 256, -1e30,
                                np.asarray(logits)), axis=-1)
    assert toks[0] == greedy[0] and toks[2] == greedy[2]
    # same key → same draw; different key may differ
    again = np.asarray(sample_batch(logits, temps, top_ps,
                                    jax.random.PRNGKey(1), 256))
    np.testing.assert_array_equal(toks, again)
