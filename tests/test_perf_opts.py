"""Beyond-paper perf features must preserve numerics (EXPERIMENTS.md §Perf)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as KREF
from repro.models import layers as L
from repro.models import perf_flags as PF


def test_banded_swa_equals_masked():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    b, s, h, hkv, hd, win = 2, 512, 4, 2, 16, 96
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, hd), jnp.float32)
    o_band = L.banded_swa_attention(q, k, v, win, q_block=64)
    o_ref = KREF.flash_prefill_ref(q, k, v, window=win)
    np.testing.assert_allclose(np.asarray(o_band), np.asarray(o_ref), atol=2e-4)


@pytest.mark.slow
def test_windowed_decode_equals_full():
    """decode with windowed KV slice == full-cache masked decode."""
    from repro.models import get_model
    m = get_model("h2o-danube-3-4b", smoke=True)  # swa, window=16 in smoke
    cfg = m.cfg
    params = m.init_params(jax.random.PRNGKey(0), jnp.float32)
    B, S = 2, 40
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    cache1 = m.init_cache(B, 64, jnp.float32)
    cache2 = m.init_cache(B, 64, jnp.float32)
    _, cache1 = m.prefill(cfg, params, tokens[:, :32], cache1)
    _, cache2 = m.prefill(cfg, params, tokens[:, :32], cache2)
    try:
        for t in range(32, S):
            PF.reset()
            lg1, cache1 = m.decode_step(cfg, params, tokens[:, t], cache1)
            PF.set_flags(windowed_decode=True)
            lg2, cache2 = m.decode_step(cfg, params, tokens[:, t], cache2)
            np.testing.assert_allclose(np.asarray(lg1, np.float32),
                                       np.asarray(lg2, np.float32), atol=2e-4)
    finally:
        PF.reset()
