"""Fast scaling (§6): pipeline steps, pre-warm, DRAM cache, NPU-fork,
autoscaler and fault recovery."""
import numpy as np
import pytest

from repro.core import (AutoscalerConfig, ClusterManager, DRAMPageCache,
                        FastScaler, ModelAsset, ModelLoader, ScaleTimings)
from repro.core.cluster import TaskExecutor
from repro.engine.distflow import DistFlow

ASSET_70B = ModelAsset("llama3-70b", n_bytes=140e9, tp=8)
ASSET_8B = ModelAsset("llama3-8b", n_bytes=16e9, tp=1)


def test_scaling_optimized_much_faster():
    scaler = FastScaler(DRAMPageCache())
    scaler.dram.preload(ASSET_70B)
    cold = scaler.scale_one(ASSET_70B, optimized=False)
    warm = scaler.scale_one(ASSET_70B, optimized=True)
    assert warm.total < cold.total / 5
    # pre-warm removes Scaler-Pre and TE-Pre-Load from the critical path
    assert warm.steps["scaler_pre"] < 1.0
    assert warm.steps["te_pre_load"] < 1.0


def test_dram_hit_vs_miss():
    dram = DRAMPageCache()
    loader = ModelLoader(dram)
    miss = loader.local_load(ASSET_8B)
    assert miss.path == "dram_miss"
    hit = loader.local_load(ASSET_8B)     # preloaded by the miss
    assert hit.path == "dram_hit"
    assert hit.seconds < miss.seconds
    assert hit.seconds >= loader.theoretical(ASSET_8B)  # fig 10: above PCIe bound


def test_pcie_contention_with_tp():
    loader = ModelLoader(DRAMPageCache())
    loader.dram.preload(ASSET_8B)
    solo = loader.local_load(ASSET_8B, n_parallel_tes=1)
    shared = loader.local_load(ASSET_8B, n_parallel_tes=8)
    assert shared.seconds > solo.seconds * 4


def test_npu_fork_ici_faster_than_dcn():
    loader = ModelLoader(DRAMPageCache())
    src = DistFlow("te-src")
    dsts = [DistFlow(f"te-{i}") for i in range(4)]
    src.link_cluster(dsts)
    ici = loader.npu_fork(ASSET_8B, src, dsts, link="ici")
    dcn = loader.npu_fork(ASSET_8B, src, dsts, link="dcn")
    assert ici.seconds < dcn.seconds


def test_npu_fork_scales_sublinearly():
    """Fig 11a: forking to 32 TEs costs much less than 32x one fork."""
    loader = ModelLoader(DRAMPageCache())
    src = DistFlow("src")
    one = loader.npu_fork(ASSET_8B, src, [DistFlow("t0")], link="ici")
    many = loader.npu_fork(ASSET_8B, src,
                           [DistFlow(f"t{i}") for i in range(32)], link="ici")
    assert many.seconds < one.seconds * 4


def test_npu_fork_contention_is_limited():
    """Fig 11b/c: dedicated transfer cores keep interference small."""
    loader = ModelLoader(DRAMPageCache())
    src = DistFlow("src")
    idle = loader.npu_fork(ASSET_8B, src, [DistFlow("a")], source_busy_frac=0.0)
    busy = loader.npu_fork(ASSET_8B, src, [DistFlow("b")], source_busy_frac=1.0)
    assert busy.seconds < idle.seconds * 1.3


def test_autoscaler_up_down_and_cooldown():
    scaler = FastScaler(DRAMPageCache(), n_prewarm_pods=8, n_prewarm_tes=8)
    cm = ClusterManager(scaler, ASSET_8B,
                        AutoscalerConfig(cooldown_s=100.0, max_tes=8))
    cm.register_te(TaskExecutor("te-0", "colocated"))
    d1 = cm.autoscale(load=0.95, slo_violations=0.0, now=1000.0)
    assert d1 > 0
    # cooldown blocks immediate re-scale
    assert cm.autoscale(load=0.95, slo_violations=0.0, now=1001.0) == 0
    # scale down on low load after cooldown
    d3 = cm.autoscale(load=0.05, slo_violations=0.0, now=2000.0)
    assert d3 == -1


def test_fault_recovery_reboots_te():
    scaler = FastScaler(DRAMPageCache())
    cm = ClusterManager(scaler, ASSET_8B, heartbeat_timeout=0.0)
    te = TaskExecutor("te-0", "colocated")
    te.fail()
    cm.register_te(te)
    rebooted = cm.check_health()
    assert rebooted == ["te-0"]
    assert te.healthy


def test_dram_cache_eviction():
    dram = DRAMPageCache(capacity_bytes=40e9)
    assert dram.preload(ASSET_8B)
    big = ModelAsset("m2", n_bytes=30e9)
    assert dram.preload(big)
    assert not dram.hit(ASSET_8B.name)   # evicted to fit
    assert dram.hit("m2")
