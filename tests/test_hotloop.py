"""NPU-centric decode hot loop tests (DESIGN.md §8).

The fused decode path — sample-in-step, persistent device-resident batch
metadata, power-of-two bucketed jits, multi-step (lax.scan) horizons with
EOS checked one horizon late — must be bit-identical to the legacy
per-step path on greedy decoding, across multi-step K ∈ {1,4,8}, bucketed
vs exact jits, qwen3 + granite, and TP ∈ {1,2}. Steady-state serving must
cost ZERO host syncs and ZERO jit compiles per step after warmup, and one
host dispatch per K-step horizon.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine import EngineConfig, FlowServe, Request, SamplingParams
from repro.engine.hotloop import DecodeHotState, pow2_bucket
from repro.engine.kv_cache import PagedKVPool
from repro.models import get_model

needs2 = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >=2 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)")

SP = SamplingParams(temperature=0.0, max_new_tokens=10, stop_on_eos=False)


@pytest.fixture(scope="module")
def qwen():
    bundle = get_model("qwen3-8b", smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    return bundle, params


@pytest.fixture(scope="module")
def granite():
    bundle = get_model("granite-moe-3b-a800m", smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    return bundle, params


def _prompts(n, length=11, seed0=0):
    return [[1] + [int(x) for x in
                   np.random.RandomState(seed0 + i).randint(3, 200, length)]
            for i in range(n)]


def _serve(model, sp=SP, n=3, tp=1, **kw):
    bundle, params = model
    ecfg = EngineConfig(tp=tp, n_pages=64, page_size=8, max_batch_tokens=32,
                        chunk_size=8, max_decode_batch=4, **kw)
    te = FlowServe(bundle, params, ecfg)
    for i, p in enumerate(_prompts(n)):
        te.add_request(Request(prompt_tokens=p, sampling=sp, req_id=f"r{i}"))
    comps = {c.req_id: c.tokens for c in te.run_to_completion()}
    assert len(comps) == n
    return [comps[f"r{i}"] for i in range(n)], te


# ---------------------------------------------------------------------------
# Greedy parity: fused+bucketed+multi-step vs the legacy per-step path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", [1, 4, 8])
def test_fused_parity_qwen3(qwen, k):
    want, te0 = _serve(qwen, fused_decode=False)
    got, te = _serve(qwen, fused_decode=True, decode_horizon=k)
    assert got == want
    assert te.sampler_dispatches == 0          # sampling fused into the step
    assert te.host_syncs < te0.host_syncs      # v1 blocked every decode step


def test_fused_parity_eos_one_horizon_late(qwen):
    """stop_on_eos with a long budget: any EOS lands mid-horizon and the
    fused path discards post-stop tokens — completions stay identical."""
    sp = SamplingParams(temperature=0.0, max_new_tokens=24, stop_on_eos=True)
    want, _ = _serve(qwen, sp=sp, fused_decode=False)
    got, _ = _serve(qwen, sp=sp, fused_decode=True, decode_horizon=8)
    assert got == want


def test_all_eos_mid_horizon_terminates(qwen, monkeypatch):
    """Worst case of late EOS checking: the ONLY running sequence stops in
    block t while block t+1 is already in flight — running empties, and the
    next plan has no decode batch. The engine must still drain the orphaned
    horizon (not livelock) and match the legacy path exactly."""
    free_run = SamplingParams(temperature=0.0, max_new_tokens=12,
                              stop_on_eos=False)
    want, _ = _serve(qwen, sp=free_run, n=1, fused_decode=False)
    fake_eos = want[0][5]          # a token greedy decoding provably emits
    import repro.engine.flowserve as FS
    monkeypatch.setattr(FS, "EOS_ID", fake_eos)
    sp = SamplingParams(temperature=0.0, max_new_tokens=12, stop_on_eos=True)
    ref, _ = _serve(qwen, sp=sp, n=1, fused_decode=False)
    got, te = _serve(qwen, sp=sp, n=1, fused_decode=True, decode_horizon=4)
    assert got == ref
    assert not te._inflight and not te._pending


def test_fused_parity_granite(granite):
    want, _ = _serve(granite, fused_decode=False)
    got, _ = _serve(granite, fused_decode=True, decode_horizon=4)
    assert got == want


@needs2
def test_fused_parity_qwen3_tp2(qwen):
    want, _ = _serve(qwen, tp=2, fused_decode=False)
    got, te = _serve(qwen, tp=2, fused_decode=True, decode_horizon=4)
    assert got == want
    assert te.host_syncs == 0


@needs2
@pytest.mark.slow
def test_fused_parity_granite_tp2(granite):
    want, _ = _serve(granite, tp=2, fused_decode=False)
    got, _ = _serve(granite, tp=2, fused_decode=True, decode_horizon=4)
    assert got == want


def test_fused_stochastic_serves_valid_tokens(qwen):
    sp = SamplingParams(temperature=0.9, top_p=0.9, max_new_tokens=8,
                        stop_on_eos=False)
    got, _ = _serve(qwen, sp=sp, fused_decode=True, decode_horizon=4)
    bundle, _ = qwen
    for toks in got:
        assert len(toks) == 8
        assert all(0 <= t < bundle.cfg.vocab_size for t in toks)


# ---------------------------------------------------------------------------
# Steady-state regression: zero syncs, zero recompiles, 1 dispatch / horizon
# ---------------------------------------------------------------------------


def test_steady_state_counters(qwen):
    bundle, params = qwen
    k = 4
    # page_size 64: one page holds any sequence here, so the steady window
    # has NO page-append events — the per-horizon dispatch count is exact
    ecfg = EngineConfig(n_pages=16, page_size=64, max_batch_tokens=32,
                        chunk_size=8, max_decode_batch=4, fused_decode=True,
                        decode_horizon=k)
    te = FlowServe(bundle, params, ecfg)
    sp = SamplingParams(temperature=0.0, max_new_tokens=48, stop_on_eos=False)
    for i, p in enumerate(_prompts(3)):
        te.add_request(Request(prompt_tokens=p, sampling=sp, req_id=f"r{i}"))
    # warm up: run until every sequence is decoding and buckets/jits exist
    for _ in range(50):
        te.step()
        if not (te.scheduler.waiting or te.scheduler.ready
                or te.scheduler.prefilling) and te.decode_steps >= 2 * k:
            break
    # the sync check is timing-statistical on a loaded 1-core CPU (the
    # horizon-late fetch can lose the race to the OS scheduler), so allow
    # one retry window; dispatch/step/compile counts stay exact per window
    for attempt in range(2):
        syncs0, compiles0 = te.host_syncs, te.jit_compiles
        disp0, dsteps0 = te.host_dispatches, te.decode_steps
        for _ in range(4):
            te.step()
        assert te.jit_compiles == compiles0        # bucketed: no recompiles
        assert te.decode_steps - dsteps0 == 4 * k  # multi-step horizons ran
        assert te.host_dispatches - disp0 == 4     # ONE dispatch per horizon
        if te.host_syncs == syncs0:                # async fetch, never blocks
            break
    else:
        pytest.fail("blocking fetch in every steady-state window")


def test_warmup_precompiles_all_buckets(qwen):
    bundle, params = qwen
    # page_size 16 keeps every sequence within 2 pages, so the small warmed
    # grid covers the whole serve trajectory
    ecfg = EngineConfig(n_pages=64, page_size=16, max_batch_tokens=32,
                        chunk_size=8, max_decode_batch=4, fused_decode=True,
                        decode_horizon=2)
    te = FlowServe(bundle, params, ecfg)
    n = te.warmup_decode(max_pages=2)
    assert n == 3 * 2 * 2          # bb in {1,2,4} x pb in {1,2} x K in {1,2}
    compiles0 = te.jit_compiles
    for i, p in enumerate(_prompts(3)):
        te.add_request(Request(prompt_tokens=p, sampling=SP, req_id=f"r{i}"))
    comps = te.run_to_completion()
    assert len(comps) == 3
    assert te.jit_compiles == compiles0    # steady serving never compiled


# ---------------------------------------------------------------------------
# Device-resident batch state: incremental events, not per-step rebuilds
# ---------------------------------------------------------------------------


def test_hot_state_incremental_events(qwen):
    bundle, _ = qwen
    pool = PagedKVPool(bundle.cfg, 32, 8)
    hot = DecodeHotState(pool)
    # "a" holds 3 pages so the page bucket starts at 4: "b" can later grow
    # 2 -> 3 pages WITHIN the bucket (incremental), not across it (rebuild)
    pages = {"a": pool.alloc(3), "b": pool.alloc(2), "c": pool.alloc(2)}
    rows = [(sid, pages[sid], 5, 7, 0.0, 1.0) for sid in ("a", "b")]
    assert hot.sync(rows) > 0                      # first sync builds rows
    assert hot.bb == 2 and hot.pb == 4
    assert hot.sync(rows) == 0                     # steady state: ZERO work
    # join grows the batch bucket -> rebuild; then steady again
    rows3 = rows + [("c", pages["c"], 5, 9, 0.7, 0.9)]
    assert hot.sync(rows3) > 0
    assert hot.bb == 4
    assert hot.sync(rows3) == 0
    # page append on one row is one incremental scatter, not a rebuild
    pages["b"].extend(pool.alloc(1))
    rebuilds0 = hot.rebuilds
    ev = hot.sync([(sid, pages[sid], 5, 7, 0.0, 1.0) if sid != "c"
                   else ("c", pages["c"], 5, 9, 0.7, 0.9)
                   for sid in ("a", "b", "c")])
    assert ev == 1 and hot.rebuilds == rebuilds0
    # leave deactivates the rows and parks their KV write on the scratch page
    slot_b, slot_c = hot.slot_of["b"], hot.slot_of["c"]
    ev = hot.sync([("a", pages["a"], 5, 7, 0.0, 1.0)])
    assert ev > 0
    active = np.asarray(hot.active)
    bt = np.asarray(hot.bt)
    lengths = np.asarray(hot.lengths)
    for slot in (slot_b, slot_c):
        assert not active[slot]
        assert lengths[slot] == 1
        assert bt[slot, 0] == pool.scratch_page()
    assert active[hot.slot_of["a"]]


def test_pow2_bucket():
    assert [pow2_bucket(n) for n in (1, 2, 3, 4, 5, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 16]


def test_req_id_reuse_joins_fresh(qwen):
    """A finished sequence's hot-state row is evicted at release, so a
    REUSED req id joins fresh instead of aliasing the stale device row
    (whose lengths/block-table still describe the finished request)."""
    bundle, params = qwen
    ecfg = EngineConfig(n_pages=64, page_size=8, max_batch_tokens=32,
                        chunk_size=8, max_decode_batch=4, fused_decode=True,
                        decode_horizon=4)
    te = FlowServe(bundle, params, ecfg)
    sp = SamplingParams(temperature=0.0, max_new_tokens=8, stop_on_eos=False)
    p = _prompts(1)[0]
    te.add_request(Request(prompt_tokens=p, sampling=sp, req_id="dup"))
    first = {c.req_id: c.tokens for c in te.run_to_completion()}["dup"]
    # a lone sequence finishes via the in-loop drain: without the explicit
    # evict its id would linger in slot_of and alias on the next serve
    assert "dup" not in (te._hot.slot_of if te._hot else {})
    te.add_request(Request(prompt_tokens=p, sampling=sp, req_id="dup"))
    second = {c.req_id: c.tokens for c in te.run_to_completion()}["dup"]
    assert second == first


# ---------------------------------------------------------------------------
# Satellite: per-batch sampling-param arrays are cached on the legacy path
# ---------------------------------------------------------------------------


def test_sampling_param_cache_keyed_on_batch(qwen):
    bundle, params = qwen
    ecfg = EngineConfig(n_pages=64, page_size=8, max_batch_tokens=32,
                        chunk_size=8, max_decode_batch=4, fused_decode=False)
    te = FlowServe(bundle, params, ecfg)
    sp = SamplingParams(temperature=0.0, max_new_tokens=6, stop_on_eos=False)
    for i, p in enumerate(_prompts(2)):
        te.add_request(Request(prompt_tokens=p, sampling=sp, req_id=f"r{i}"))
    while te.has_work() and te.decode_steps < 1:
        te.step()
    key0, temps0 = te._sp_cache[0], te._sp_cache[1]
    assert key0 == ("r0", "r1")
    te.step()                          # same batch: the arrays are reused
    assert te._sp_cache[1] is temps0
    te.run_to_completion()             # finishes invalidate via key change
    te.add_request(Request(prompt_tokens=_prompts(1, seed0=9)[0],
                           sampling=sp, req_id="r9"))
    te.run_to_completion()
    assert te._sp_cache[0] == ("r9",)
