"""Serving-plane benchmark (DESIGN.md §9): Algorithm 1 vs round-robin over
a LIVE mixed fleet — one PD-disaggregated pair + one PD-colocated TE, all
real FLOWSERVE engines (T1 numerics on smoke configs).

Closed-loop driver: Poisson arrivals feed the JE while it steps the fleet;
agent sessions are genuinely closed-loop (turn t+1's prompt extends turn
t's prompt + completion, submitted the moment t completes). Three traffic
mixes:

* ``longP_shortD`` — long prefill / short decode (summarization-like);
* ``shortP_longD`` — short prefill / long decode (generation-like);
* ``agent``       — multi-turn prefix-sharing sessions (locality-bound).

Per (mix, policy): mean/p90 TTFT, mean TPOT, goodput (completions meeting
the TTFT SLO per wall second), tok/s, the Algorithm-1 decision counters,
and per-request greedy-token PARITY against a single colocated TE serving
the same closed loop — the placement layer must never change tokens.

    PYTHONPATH=src python benchmarks/bench_serving_plane.py [--requests 12]
        [--rps 8] [--max-wall 120]

Also exposes run() -> CSV rows for benchmarks/run.py (key
``serving_plane``; ``--json`` → BENCH_serving_plane.json).
"""
from __future__ import annotations

import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = \
        (_flags + " --xla_force_host_platform_device_count=8").strip()

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.abstractions import RequestType, UserRequest
from repro.core.serving_plane import ServingJobEngine, TopologySpec
from repro.engine import EngineConfig, SamplingParams
from repro.models import get_model

# Goodput SLO: machine-relative (CPU smoke engines timeshare one host, so
# absolute latencies are meaningless) — a completion counts toward goodput
# when its TTFT is within SLO_FACTOR x the single-TE reference run's
# median TTFT for the same mix.
SLO_FACTOR = 1.5


# --------------------------------------------------------------- workloads
def _tok(rng, n, lo, hi):
    return [1] + [int(x) for x in rng.randint(lo, hi, n)]


def _turn_suffix(mix_seed: int, session: int, turn: int):
    """Deterministic per-(session, turn) user tokens: the closed-loop agent
    driver must build IDENTICAL turn prompts regardless of the order
    completions happen to arrive in (parity across policies)."""
    rng = np.random.RandomState(mix_seed + 131 * session + 7 * turn)
    return _tok(rng, 8, 160, 240)[1:]


def make_mix(mix: str, n: int, rps: float, seed: int = 0):
    """Open-loop arrivals [(t, key, tokens, max_new)] + closed-loop session
    continuations (agent mix). Token spaces are disjoint per mix so prefix
    caches never couple mixes."""
    rng = np.random.RandomState(seed)
    gaps = rng.exponential(1.0 / rps, size=n)
    ts = np.cumsum(gaps)
    arrivals, sessions = [], {}
    if mix == "longP_shortD":
        for i in range(n):
            arrivals.append((float(ts[i]), f"{mix}-{i}",
                             _tok(rng, 72 + int(rng.randint(0, 24)), 3, 80), 6))
    elif mix == "shortP_longD":
        for i in range(n):
            arrivals.append((float(ts[i]), f"{mix}-{i}",
                             _tok(rng, 6 + int(rng.randint(0, 8)), 80, 160), 24))
    elif mix == "agent":
        n_sessions = max(2, n // 3)
        for s in range(n_sessions):
            prompt = _tok(np.random.RandomState(seed + 977 * s), 24, 160, 240)
            arrivals.append((float(ts[s]), f"{mix}-s{s}t0", prompt, 8))
            sessions[f"{mix}-s{s}t0"] = (s, 0)
        # later turns spawn on completion (closed loop); 3 turns/session
    else:
        raise ValueError(mix)
    return arrivals, sessions


# --------------------------------------------------------------- driver
def drive(je: ServingJobEngine, mix: str, n: int, rps: float,
          max_wall: float, seed: int = 0):
    """Closed-loop run: submit Poisson arrivals while stepping the fleet;
    agent sessions submit their next turn the moment the previous one
    completes. Returns {key: Completion}."""
    arrivals, sessions = make_mix(mix, n, rps, seed)
    sp = {key: SamplingParams(temperature=0.0, max_new_tokens=mn,
                              stop_on_eos=False)
          for _, key, _, mn in arrivals}
    prompts = {key: toks for _, key, toks, _ in arrivals}
    done = {}
    i = 0
    t0 = time.monotonic()
    while True:
        now = time.monotonic() - t0
        while i < len(arrivals) and arrivals[i][0] <= now:
            _, key, toks, mn = arrivals[i]
            je.submit(toks, sampling=sp[key],
                      request=UserRequest(rtype=RequestType.CHAT,
                                          payload={"tokens": toks},
                                          req_id=key))
            i += 1
        for c in je.step():
            done[c.req_id] = c
            if c.req_id in sessions:            # agent: next turn now
                s, t = sessions.pop(c.req_id)
                if t < 2:
                    key = f"{mix}-s{s}t{t + 1}"
                    toks = (prompts[c.req_id] + list(c.tokens)
                            + _turn_suffix(seed, s, t + 1))
                    prompts[key] = toks
                    sessions[key] = (s, t + 1)
                    sp[key] = SamplingParams(temperature=0.0,
                                             max_new_tokens=8,
                                             stop_on_eos=False)
                    arrivals.append((now, key, toks, 8))
        if i >= len(arrivals) and not je.has_work() and not sessions:
            break
        if now > max_wall:
            break
    wall = time.monotonic() - t0
    return done, wall


def _metrics(done: dict, wall: float, slo_ttft: float) -> dict:
    ttfts = np.asarray([c.ttft for c in done.values()])
    tpots = np.asarray([c.tpot for c in done.values()])
    n_tok = sum(len(c.tokens) for c in done.values())
    return {
        "n": len(done),
        "ttft_mean_ms": float(ttfts.mean() * 1e3) if len(ttfts) else 0.0,
        "ttft_p90_ms": float(np.percentile(ttfts, 90) * 1e3)
        if len(ttfts) else 0.0,
        "tpot_ms": float(tpots.mean() * 1e3) if len(tpots) else 0.0,
        "slo_ttft_ms": slo_ttft * 1e3,
        "goodput_rps": sum(1 for t in ttfts if t <= slo_ttft) / wall,
        "tok_s": n_tok / wall,
        "wall_s": wall,
    }


# --------------------------------------------------------------- harness
def _plane(bundle, params, topo: TopologySpec, policy: str,
           heat) -> ServingJobEngine:
    hm, lens, ratios = heat
    ecfg = EngineConfig(n_pages=256, page_size=8, max_batch_tokens=64,
                        chunk_size=16, max_decode_batch=8)
    return ServingJobEngine(bundle, params, topo, heatmap=hm,
                            prefill_lens=lens, decode_ratios=ratios,
                            policy=policy, ecfg=ecfg)


def _warm(je: ServingJobEngine) -> None:
    sp = SamplingParams(temperature=0.0, max_new_tokens=4, stop_on_eos=False)
    for i in range(4):
        je.submit([1] + [250 + (i % 4)] * (8 + 24 * (i % 2)), sampling=sp)
    je.run_to_completion()


def bench(n: int = 9, rps: float = 1.5, max_wall: float = 150.0,
          arch: str = "qwen3-8b"):
    bundle = get_model(arch, smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    # smoke-scale heatmap: the long-prefill/short-decode cell favors the PD
    # pair, everything else favors colocated — the same table shape
    # HeatmapStudy produces at production scale (§5.3), re-anchored to
    # smoke prompt lengths so pd_aware has a real decision to make.
    heat = (np.asarray([[-1.0, -1.0], [+1.0, -1.0]]), [24, 84], [0.1, 3.0])
    topo = TopologySpec(pd=1, colo=1)
    planes = {pol: _plane(bundle, params, topo, pol, heat)
              for pol in ("dist_sched", "round_robin")}
    ref = _plane(bundle, params, TopologySpec(pd=0, colo=1),
                 "round_robin", heat)
    for je in [*planes.values(), ref]:
        _warm(je)

    results = {}
    for mix in ("longP_shortD", "shortP_longD", "agent"):
        ref_done, ref_wall = drive(ref, mix, n, rps, max_wall, seed=7)
        ref_toks = {k: list(c.tokens) for k, c in ref_done.items()}
        slo = SLO_FACTOR * float(np.median([c.ttft
                                            for c in ref_done.values()]))
        results[mix] = {"ref": _metrics(ref_done, ref_wall, slo)}
        for pol, je in planes.items():
            d0 = dict(je.scheduler.decisions)
            done, wall = drive(je, mix, n, rps, max_wall, seed=7)
            m = _metrics(done, wall, slo)
            m["decisions"] = {k: je.scheduler.decisions[k] - d0[k]
                              for k in d0}
            m["parity"] = (len(done) == len(ref_done)
                           and all(list(done[k].tokens) == ref_toks[k]
                                   for k in ref_toks))
            results[mix][pol] = m
    return results


def run() -> list:
    """CSV rows for benchmarks/run.py: (name, value, derived)."""
    rows = []
    results = bench()
    wins = []
    for mix, by_pol in results.items():
        for pol in ("dist_sched", "round_robin"):
            m = by_pol[pol]
            dec = m["decisions"]
            rows.append((
                f"serving_plane_{mix}_{pol}", m["ttft_mean_ms"] * 1e3,
                f"ttft_p90_ms={m['ttft_p90_ms']:.0f};"
                f"tpot_ms={m['tpot_ms']:.1f};"
                f"goodput_rps={m['goodput_rps']:.2f}"
                f"@slo{m['slo_ttft_ms']:.0f}ms;"
                f"tok_s={m['tok_s']:.1f};n={m['n']};"
                f"parity={m['parity']};"
                f"decisions=disagg:{dec['pd_disagg']}/colo:{dec['pd_colo']}"
                f"/loc:{dec['locality']}/load:{dec['load']}"))
        ds, rr = by_pol["dist_sched"], by_pol["round_robin"]
        if (ds["ttft_mean_ms"] < rr["ttft_mean_ms"]
                or ds["goodput_rps"] > rr["goodput_rps"]):
            wins.append(mix)
    rows.append(("serving_plane_dist_sched_wins", float(len(wins)),
                 f"mixes_where_dist_sched_beats_rr_on_ttft_or_goodput="
                 f"{','.join(wins) or 'none'}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--requests", type=int, default=9)
    ap.add_argument("--rps", type=float, default=1.5)
    ap.add_argument("--max-wall", type=float, default=150.0)
    args = ap.parse_args()

    print(f"devices={jax.device_count()} arch={args.arch}-smoke "
          f"topology=pd=1,colo=1 n={args.requests} rps={args.rps} "
          f"slo=TTFT<={SLO_FACTOR}x ref median")
    results = bench(args.requests, args.rps, args.max_wall, args.arch)
    print(f"{'mix':>14} {'policy':>12} {'n':>3} {'ttft':>8} {'p90':>8} "
          f"{'tpot':>7} {'goodput':>8} {'tok/s':>7} {'parity':>7}  decisions")
    for mix, by_pol in results.items():
        for pol in ("dist_sched", "round_robin", "ref"):
            m = by_pol[pol]
            dec = m.get("decisions", {})
            dec_s = (f"disagg:{dec['pd_disagg']} colo:{dec['pd_colo']} "
                     f"loc:{dec['locality']} load:{dec['load']}"
                     if dec else "-")
            print(f"{mix:>14} {pol:>12} {m['n']:>3} "
                  f"{m['ttft_mean_ms']:>6.0f}ms {m['ttft_p90_ms']:>6.0f}ms "
                  f"{m['tpot_ms']:>5.1f}ms {m['goodput_rps']:>8.2f} "
                  f"{m['tok_s']:>7.1f} {m.get('parity', '-')!s:>7}  {dec_s}")


if __name__ == "__main__":
    main()
