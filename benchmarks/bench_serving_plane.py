"""Serving-plane benchmark (DESIGN.md §9): Algorithm 1 vs round-robin over
a LIVE mixed fleet — one PD-disaggregated pair + one PD-colocated TE, all
real FLOWSERVE engines (T1 numerics on smoke configs).

Closed-loop driver: Poisson arrivals feed the JE while it steps the fleet;
agent sessions are genuinely closed-loop (turn t+1's prompt extends turn
t's prompt + completion, submitted the moment t completes). Three traffic
mixes:

* ``longP_shortD`` — long prefill / short decode (summarization-like);
* ``shortP_longD`` — short prefill / long decode (generation-like);
* ``agent``       — multi-turn prefix-sharing sessions (locality-bound).

Per (mix, policy): mean/p90 TTFT, mean TPOT, goodput (completions meeting
the TTFT SLO per wall second), tok/s, the Algorithm-1 decision counters,
and per-request greedy-token PARITY against a single colocated TE serving
the same closed loop — the placement layer must never change tokens.

Two elastic-fleet axes ride along (core/fleet.py):

* ``--fleet-threads N`` — the SAME deterministic batch through an
  identical fleet stepped serially vs over per-TE executor threads:
  reports the wall-clock speedup at EQUAL policy decisions and greedy
  parity (every placement happens before the first step, so the decision
  stream cannot depend on thread interleaving);
* scale-in scenario — a skewed burst forks a TE (LoadSpreadTrigger),
  the post-burst idle drains one (DrainTrigger → §7 migrate-out →
  RELEASED): reports peak vs final SERVING TEs and burst parity vs the
  single-TE reference.

    PYTHONPATH=src python benchmarks/bench_serving_plane.py [--requests 12]
        [--rps 8] [--max-wall 120] [--fleet-threads 4]

Also exposes run() -> CSV rows for benchmarks/run.py (key
``serving_plane``; ``--json`` → BENCH_serving_plane.json).
"""
from __future__ import annotations

import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = \
        (_flags + " --xla_force_host_platform_device_count=8").strip()

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.abstractions import RequestType, UserRequest
from repro.core.serving_plane import ServingJobEngine, TopologySpec
from repro.engine import EngineConfig, SamplingParams
from repro.models import get_model

# Goodput SLO: machine-relative (CPU smoke engines timeshare one host, so
# absolute latencies are meaningless) — a completion counts toward goodput
# when its TTFT is within SLO_FACTOR x the single-TE reference run's
# median TTFT for the same mix.
SLO_FACTOR = 1.5


# --------------------------------------------------------------- workloads
def _tok(rng, n, lo, hi):
    return [1] + [int(x) for x in rng.randint(lo, hi, n)]


def _turn_suffix(mix_seed: int, session: int, turn: int):
    """Deterministic per-(session, turn) user tokens: the closed-loop agent
    driver must build IDENTICAL turn prompts regardless of the order
    completions happen to arrive in (parity across policies)."""
    rng = np.random.RandomState(mix_seed + 131 * session + 7 * turn)
    return _tok(rng, 8, 160, 240)[1:]


def make_mix(mix: str, n: int, rps: float, seed: int = 0):
    """Open-loop arrivals [(t, key, tokens, max_new)] + closed-loop session
    continuations (agent mix). Token spaces are disjoint per mix so prefix
    caches never couple mixes."""
    rng = np.random.RandomState(seed)
    gaps = rng.exponential(1.0 / rps, size=n)
    ts = np.cumsum(gaps)
    arrivals, sessions = [], {}
    if mix == "longP_shortD":
        for i in range(n):
            arrivals.append((float(ts[i]), f"{mix}-{i}",
                             _tok(rng, 72 + int(rng.randint(0, 24)), 3, 80), 6))
    elif mix == "shortP_longD":
        for i in range(n):
            arrivals.append((float(ts[i]), f"{mix}-{i}",
                             _tok(rng, 6 + int(rng.randint(0, 8)), 80, 160), 24))
    elif mix == "agent":
        n_sessions = max(2, n // 3)
        for s in range(n_sessions):
            prompt = _tok(np.random.RandomState(seed + 977 * s), 24, 160, 240)
            arrivals.append((float(ts[s]), f"{mix}-s{s}t0", prompt, 8))
            sessions[f"{mix}-s{s}t0"] = (s, 0)
        # later turns spawn on completion (closed loop); 3 turns/session
    else:
        raise ValueError(mix)
    return arrivals, sessions


# --------------------------------------------------------------- driver
def drive(je: ServingJobEngine, mix: str, n: int, rps: float,
          max_wall: float, seed: int = 0):
    """Closed-loop run: submit Poisson arrivals while stepping the fleet;
    agent sessions submit their next turn the moment the previous one
    completes. Returns {key: Completion}."""
    arrivals, sessions = make_mix(mix, n, rps, seed)
    sp = {key: SamplingParams(temperature=0.0, max_new_tokens=mn,
                              stop_on_eos=False)
          for _, key, _, mn in arrivals}
    prompts = {key: toks for _, key, toks, _ in arrivals}
    done = {}
    i = 0
    t0 = time.monotonic()
    while True:
        now = time.monotonic() - t0
        while i < len(arrivals) and arrivals[i][0] <= now:
            _, key, toks, mn = arrivals[i]
            je.submit(toks, sampling=sp[key],
                      request=UserRequest(rtype=RequestType.CHAT,
                                          payload={"tokens": toks},
                                          req_id=key))
            i += 1
        for c in je.step():
            done[c.req_id] = c
            if c.req_id in sessions:            # agent: next turn now
                s, t = sessions.pop(c.req_id)
                if t < 2:
                    key = f"{mix}-s{s}t{t + 1}"
                    toks = (prompts[c.req_id] + list(c.tokens)
                            + _turn_suffix(seed, s, t + 1))
                    prompts[key] = toks
                    sessions[key] = (s, t + 1)
                    sp[key] = SamplingParams(temperature=0.0,
                                             max_new_tokens=8,
                                             stop_on_eos=False)
                    arrivals.append((now, key, toks, 8))
        if i >= len(arrivals) and not je.has_work() and not sessions:
            break
        if now > max_wall:
            break
    wall = time.monotonic() - t0
    return done, wall


def _metrics(done: dict, wall: float, slo_ttft: float) -> dict:
    ttfts = np.asarray([c.ttft for c in done.values()])
    tpots = np.asarray([c.tpot for c in done.values()])
    n_tok = sum(len(c.tokens) for c in done.values())
    return {
        "n": len(done),
        "ttft_mean_ms": float(ttfts.mean() * 1e3) if len(ttfts) else 0.0,
        "ttft_p90_ms": float(np.percentile(ttfts, 90) * 1e3)
        if len(ttfts) else 0.0,
        "tpot_ms": float(tpots.mean() * 1e3) if len(tpots) else 0.0,
        "slo_ttft_ms": slo_ttft * 1e3,
        "goodput_rps": sum(1 for t in ttfts if t <= slo_ttft) / wall,
        "tok_s": n_tok / wall,
        "wall_s": wall,
    }


# --------------------------------------------------------------- harness
def _plane(bundle, params, topo: TopologySpec, policy: str,
           heat, **kw) -> ServingJobEngine:
    hm, lens, ratios = heat
    ecfg = EngineConfig(n_pages=256, page_size=8, max_batch_tokens=64,
                        chunk_size=16, max_decode_batch=8)
    return ServingJobEngine(bundle, params, topo, heatmap=hm,
                            prefill_lens=lens, decode_ratios=ratios,
                            policy=policy, ecfg=ecfg, **kw)


def _warm(je: ServingJobEngine) -> None:
    sp = SamplingParams(temperature=0.0, max_new_tokens=4, stop_on_eos=False)
    for i in range(4):
        je.submit([1] + [250 + (i % 4)] * (8 + 24 * (i % 2)), sampling=sp)
    je.run_to_completion()


def bench(n: int = 9, rps: float = 1.5, max_wall: float = 150.0,
          arch: str = "qwen3-8b"):
    bundle = get_model(arch, smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    # smoke-scale heatmap: the long-prefill/short-decode cell favors the PD
    # pair, everything else favors colocated — the same table shape
    # HeatmapStudy produces at production scale (§5.3), re-anchored to
    # smoke prompt lengths so pd_aware has a real decision to make.
    heat = (np.asarray([[-1.0, -1.0], [+1.0, -1.0]]), [24, 84], [0.1, 3.0])
    topo = TopologySpec(pd=1, colo=1)
    planes = {pol: _plane(bundle, params, topo, pol, heat)
              for pol in ("dist_sched", "round_robin")}
    ref = _plane(bundle, params, TopologySpec(pd=0, colo=1),
                 "round_robin", heat)
    for je in [*planes.values(), ref]:
        _warm(je)

    results = {}
    for mix in ("longP_shortD", "shortP_longD", "agent"):
        ref_done, ref_wall = drive(ref, mix, n, rps, max_wall, seed=7)
        ref_toks = {k: list(c.tokens) for k, c in ref_done.items()}
        slo = SLO_FACTOR * float(np.median([c.ttft
                                            for c in ref_done.values()]))
        results[mix] = {"ref": _metrics(ref_done, ref_wall, slo)}
        for pol, je in planes.items():
            d0 = dict(je.scheduler.decisions)
            p0 = sum(e.prefill_dispatches for e in je.engines)
            h0 = sum(e.host_dispatches for e in je.engines)
            done, wall = drive(je, mix, n, rps, max_wall, seed=7)
            m = _metrics(done, wall, slo)
            m["decisions"] = {k: je.scheduler.decisions[k] - d0[k]
                              for k in d0}
            # fleet-wide dispatch split (§12): with batched_prefill the
            # prefill side of a mix collapses to ~1 dispatch per step
            # regardless of how many prompts the step's plan packs
            m["prefill_dispatches"] = (
                sum(e.prefill_dispatches for e in je.engines) - p0)
            m["decode_dispatches"] = (
                sum(e.host_dispatches for e in je.engines) - h0)
            m["parity"] = (len(done) == len(ref_done)
                           and all(list(done[k].tokens) == ref_toks[k]
                                   for k in ref_toks))
            results[mix][pol] = m
    return results


def bench_fleet_axis(threads: int = 4, n_units: int = 3, n_req: int = 9,
                     prompt_len: int = 200, max_new: int = 32,
                     reps: int = 3) -> dict:
    """Serial vs concurrent stepping of the SAME fleet (core/fleet.py).

    One plane of ``n_units`` colocated TEs — each on its OWN device window
    (tp=1 per-TE device pinning, DESIGN.md §9) — serves identical-shape
    batches with ``fleet_threads`` toggled per phase, interleaved
    best-of-``reps`` (the bench_decode_hotloop protocol, so late jit
    buckets can't bias either mode). Every request is submitted before
    the first step, so all Algorithm-1 decisions happen up front and must
    be IDENTICAL across every phase — the executor layer may only change
    wall-clock, never placement (token parity serial-vs-threaded on one
    batch is enforced by tests/test_fleet_lifecycle.py).

    The model is a bench-scale config (d_model 256 vs the smoke 64): at
    smoke scale a step is pure host-side python and the GIL serializes it,
    so per-dispatch device work has to be real for executor overlap to be
    visible at all — which is exactly the production regime."""
    from dataclasses import replace as _drep

    from repro.configs.base import get_config, smoke_config
    cfg = _drep(smoke_config(get_config("qwen3-8b")), name="qwen3-8b-bench",
                d_model=256, n_heads=8, head_dim=32, d_ff=512)
    bundle = get_model(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    heat = (-np.ones((2, 2)), [24, 84], [0.1, 3.0])
    ecfg = EngineConfig(n_pages=128, page_size=8, max_batch_tokens=128,
                        chunk_size=64, max_decode_batch=4)
    je = ServingJobEngine(bundle, params, TopologySpec(pd=0, colo=n_units),
                          heatmap=heat[0], prefill_lens=heat[1],
                          decode_ratios=heat[2], ecfg=ecfg)
    sp = SamplingParams(temperature=0.0, max_new_tokens=max_new,
                        stop_on_eos=False)
    seed = [0]

    def phase(ft: int):
        je.fleet_threads = ft
        seed[0] += 1
        rng = np.random.RandomState(1000 + seed[0])
        d0 = dict(je.scheduler.decisions)
        for _ in range(n_req):
            je.submit(_tok(rng, prompt_len, 3, 200), sampling=sp)
        t0 = time.monotonic()
        n_done = len(je.run_to_completion())
        return (time.monotonic() - t0,
                {k: je.scheduler.decisions[k] - d0[k] for k in d0}, n_done)

    phase(0), phase(0)                    # warm twice: late-bucket compiles
    s_walls, t_walls, decs, dones = [], [], [], []
    for _ in range(reps):
        w, d, n_done = phase(1)
        s_walls.append(w); decs.append(d); dones.append(n_done)
        w, d, n_done = phase(threads)
        t_walls.append(w); decs.append(d); dones.append(n_done)
    je.close()
    return {
        "serial": {"wall_s": min(s_walls), "walls": s_walls},
        "threads": {"wall_s": min(t_walls), "walls": t_walls},
        "threads_n": threads,
        "n_units": n_units,
        "n": n_req,
        "speedup": min(s_walls) / max(1e-9, min(t_walls)),
        "decisions_equal": all(d == decs[0] for d in decs),
        "all_completed": all(n == n_req for n in dones),
    }


def bench_scale_in(bundle, params, heat) -> dict:
    """Elastic scale-out THEN scale-in (DESIGN.md §9): a skewed burst
    breaches LoadSpreadTrigger (NPU-fork), the post-burst idle breaches
    DrainTrigger (drain → §7 migrate-out → RELEASED + device window
    freed). Ends with fewer SERVING TEs than peak; every burst request
    keeps greedy parity vs a single colocated TE."""
    from repro.core.scaling import (DrainTrigger, DRAMPageCache, FastScaler,
                                    LoadSpreadTrigger)
    sp = SamplingParams(temperature=0.0, max_new_tokens=12,
                        stop_on_eos=False)
    prompts = [_tok(np.random.RandomState(31 + i), 64 if i % 2 == 0 else 6,
                    3, 200) for i in range(8)]
    ref = _plane(bundle, params, TopologySpec(pd=0, colo=1),
                 "round_robin", heat)
    _warm(ref)
    ref_ids = [ref.submit(list(p), sampling=sp) for p in prompts]
    ref_toks = {c.req_id: list(c.tokens) for c in ref.run_to_completion()}
    # round-robin alternates TEs; alternating huge/tiny prompts skews load
    je = _plane(bundle, params, TopologySpec(pd=0, colo=2), "round_robin",
                heat, scaler=FastScaler(DRAMPageCache()),
                trigger=LoadSpreadTrigger(threshold=0.5, patience=2,
                                          min_load=4.0, max_fires=2),
                drain_trigger=DrainTrigger(low_watermark=2.0, patience=4,
                                           min_serving=1))
    _warm(je)
    n_warm = len(je.completions)          # exclude warmup from parity
    rids = [je.submit(list(p), sampling=sp) for p in prompts]
    peak = je.n_serving()
    t0 = time.monotonic()
    while je.has_work():
        je.step()
        peak = max(peak, je.n_serving())
    for _ in range(200):                  # post-burst idle: drains fire
        je.step()
        if not je.has_work() and je.n_serving() < peak:
            break
    comps = {c.req_id: list(c.tokens) for c in je.completions[n_warm:]}
    kinds = [e["kind"] for e in je.scale_events]
    return {
        "peak_serving": peak,
        "final_serving": je.n_serving(),
        "forks": kinds.count("fork"),
        "releases": kinds.count("release"),
        "wall_s": time.monotonic() - t0,
        "parity": (len(comps) == len(ref_toks)
                   and all(comps.get(r) == ref_toks[ri]
                           for r, ri in zip(rids, ref_ids))),
    }


def run() -> list:
    """CSV rows for benchmarks/run.py: (name, value, derived)."""
    rows = []
    results = bench()
    wins = []
    for mix, by_pol in results.items():
        for pol in ("dist_sched", "round_robin"):
            m = by_pol[pol]
            dec = m["decisions"]
            rows.append((
                f"serving_plane_{mix}_{pol}", m["ttft_mean_ms"] * 1e3,
                f"ttft_p90_ms={m['ttft_p90_ms']:.0f};"
                f"tpot_ms={m['tpot_ms']:.1f};"
                f"goodput_rps={m['goodput_rps']:.2f}"
                f"@slo{m['slo_ttft_ms']:.0f}ms;"
                f"tok_s={m['tok_s']:.1f};n={m['n']};"
                f"parity={m['parity']};"
                f"dispatches=prefill:{m['prefill_dispatches']}"
                f"/decode:{m['decode_dispatches']};"
                f"decisions=disagg:{dec['pd_disagg']}/colo:{dec['pd_colo']}"
                f"/loc:{dec['locality']}/load:{dec['load']}"))
        ds, rr = by_pol["dist_sched"], by_pol["round_robin"]
        if (ds["ttft_mean_ms"] < rr["ttft_mean_ms"]
                or ds["goodput_rps"] > rr["goodput_rps"]):
            wins.append(mix)
    rows.append(("serving_plane_dist_sched_wins", float(len(wins)),
                 f"mixes_where_dist_sched_beats_rr_on_ttft_or_goodput="
                 f"{','.join(wins) or 'none'}"))
    fa = bench_fleet_axis()
    rows.append((
        "serving_plane_fleet_speedup", fa["speedup"],
        f"serial_s={fa['serial']['wall_s']:.2f};"
        f"threads_s={fa['threads']['wall_s']:.2f};"
        f"fleet_threads={fa['threads_n']};units={fa['n_units']};"
        f"decisions_equal={fa['decisions_equal']};"
        f"all_completed={fa['all_completed']};n={fa['n']}"))
    bundle = get_model("qwen3-8b", smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    heat = (np.asarray([[-1.0, -1.0], [+1.0, -1.0]]), [24, 84], [0.1, 3.0])
    si = bench_scale_in(bundle, params, heat)
    rows.append((
        "serving_plane_scale_in", float(si["peak_serving"]
                                        - si["final_serving"]),
        f"peak_serving={si['peak_serving']};"
        f"final_serving={si['final_serving']};"
        f"forks={si['forks']};releases={si['releases']};"
        f"parity={si['parity']}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--requests", type=int, default=9)
    ap.add_argument("--rps", type=float, default=1.5)
    ap.add_argument("--max-wall", type=float, default=150.0)
    ap.add_argument("--fleet-threads", type=int, default=4,
                    help="concurrent axis: per-TE executor threads for the "
                         "serial-vs-concurrent wall-clock comparison "
                         "(core/fleet.py); 0 skips the axis")
    args = ap.parse_args()

    print(f"devices={jax.device_count()} arch={args.arch}-smoke "
          f"topology=pd=1,colo=1 n={args.requests} rps={args.rps} "
          f"slo=TTFT<={SLO_FACTOR}x ref median")
    results = bench(args.requests, args.rps, args.max_wall, args.arch)
    print(f"{'mix':>14} {'policy':>12} {'n':>3} {'ttft':>8} {'p90':>8} "
          f"{'tpot':>7} {'goodput':>8} {'tok/s':>7} {'parity':>7}  decisions")
    for mix, by_pol in results.items():
        for pol in ("dist_sched", "round_robin", "ref"):
            m = by_pol[pol]
            dec = m.get("decisions", {})
            dec_s = (f"disagg:{dec['pd_disagg']} colo:{dec['pd_colo']} "
                     f"loc:{dec['locality']} load:{dec['load']}"
                     if dec else "-")
            if "prefill_dispatches" in m:
                dec_s += (f"  disp=p:{m['prefill_dispatches']}"
                          f"/d:{m['decode_dispatches']}")
            print(f"{mix:>14} {pol:>12} {m['n']:>3} "
                  f"{m['ttft_mean_ms']:>6.0f}ms {m['ttft_p90_ms']:>6.0f}ms "
                  f"{m['tpot_ms']:>5.1f}ms {m['goodput_rps']:>8.2f} "
                  f"{m['tok_s']:>7.1f} {m.get('parity', '-')!s:>7}  {dec_s}")

    if args.fleet_threads > 1:
        fa = bench_fleet_axis(threads=args.fleet_threads)
        print(f"\nfleet executors ({fa['n_units']} colocated units, "
              f"best-of-3 interleaved): serial {fa['serial']['wall_s']:.2f}s "
              f"vs {fa['threads_n']} threads {fa['threads']['wall_s']:.2f}s "
              f"-> {fa['speedup']:.2f}x (decisions_equal="
              f"{fa['decisions_equal']} all_completed={fa['all_completed']})")
    bundle = get_model(args.arch, smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    heat = (np.asarray([[-1.0, -1.0], [+1.0, -1.0]]), [24, 84], [0.1, 3.0])
    si = bench_scale_in(bundle, params, heat)
    print(f"scale-in: peak {si['peak_serving']} SERVING TEs -> final "
          f"{si['final_serving']} (forks={si['forks']} "
          f"releases={si['releases']} parity={si['parity']})")


if __name__ == "__main__":
    main()
