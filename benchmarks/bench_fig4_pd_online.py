"""Figure 4 — PD-disaggregated vs PD-colocated online serving.

Paper setup: 34B model, TP=4, internal trace (~2K input, 200 output), RPS
0.2→1.2. Setups: (1) 2P+2D, (2) 2P+1D, (3) 4× colocated. Tier T3: the
calibrated simulator prices work with the v5e cost model; schedulers and
queueing are real code. Reported: mean JCT and mean TPOT per RPS."""
from __future__ import annotations

import numpy as np

from benchmarks.simcluster import SimTE, poisson_trace, run_cluster
from repro.configs.base import ModelConfig
from repro.core.perf_model import TECostModel, TEHardware

# 34B-dense stand-in (the paper's model is unnamed): 48L×d6144 ≈ 34B
CFG_34B = ModelConfig(name="dense-34b", family="dense", n_layers=48,
                      d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
                      d_ff=24576, vocab_size=32000)


def _trace(rps, seed=0):
    return poisson_trace(rps, duration=120.0, seed=seed,
                         p_sampler=lambda rng: (2048, 200))


def _setup(kind: str):
    cost = TECostModel(CFG_34B, TEHardware(n_chips=4))
    if kind == "2P2D":
        return [SimTE("pd0", "pd_pair", cost), SimTE("pd1", "pd_pair", cost)]
    if kind == "2P1D":
        # asymmetric pair: model as one pd TE with 1.5x prefill capacity
        te = SimTE("pd0", "pd_pair", cost)
        return [te, SimTE("pd1", "pd_pair", cost, max_batch=8)]
    return [SimTE(f"c{i}", "colocated", cost) for i in range(4)]


def run() -> list:
    rows = []
    for rps in (0.2, 0.4, 0.6, 0.8, 1.0, 1.2):
        for kind in ("2P2D", "2P1D", "colo4"):
            tes = _setup(kind)
            state = {"i": 0}

            def rr(req):
                te = tes[state["i"] % len(tes)]
                state["i"] += 1
                return te

            done = run_cluster(tes, _trace(rps), rr, horizon=600.0)
            if not done:
                continue
            jct = float(np.mean([r.jct for r in done]))
            tpot = float(np.mean([r.tpot for r in done])) * 1e3
            rows.append((f"fig4_{kind}_rps{rps}", jct * 1e6,
                         f"jct_s={jct:.2f};tpot_ms={tpot:.1f};n={len(done)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
