"""Figure 11 — NPU-fork scalability and sensitivity (Llama3-8B TP=1 over
the scaled-up fabric): (a) parallel fork to N TEs, (b) source busy
prefilling, (c) source busy decoding. Tier T3 + real DistFlow broadcast."""
from __future__ import annotations

from repro.core import DRAMPageCache, ModelAsset, ModelLoader
from repro.engine.distflow import DistFlow

ASSET = ModelAsset("llama3-8b", 16e9, tp=1)


def run() -> list:
    loader = ModelLoader(DRAMPageCache())
    rows = []
    for n in (1, 2, 4, 8, 16, 32, 64):
        src = DistFlow("src")
        r = loader.npu_fork(ASSET, src, [DistFlow(f"t{i}") for i in range(n)],
                            link="ici")
        rows.append((f"fig11a_fork_x{n}_s", r.seconds * 1e6,
                     f"per_te={r.seconds:.2f}s"))
    for busy, label in ((0.0, "idle"), (0.5, "prefill_4k"), (1.0, "prefill_32k")):
        src = DistFlow("src")
        r = loader.npu_fork(ASSET, src, [DistFlow(f"t{i}") for i in range(32)],
                            link="ici", source_busy_frac=busy)
        rows.append((f"fig11b_src_{label}_s", r.seconds * 1e6, ""))
    for batch in (0, 8, 32, 128):
        src = DistFlow("src")
        r = loader.npu_fork(ASSET, src, [DistFlow(f"t{i}") for i in range(32)],
                            link="ici", source_busy_frac=min(1.0, batch / 128))
        rows.append((f"fig11c_decode_b{batch}_s", r.seconds * 1e6, ""))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
