"""Roofline table (deliverable g): reads artifacts/dryrun.jsonl (written by
repro.launch.dryrun --probes) and prints per-cell terms. Tier T2."""
from __future__ import annotations

import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                   "dryrun_probes.jsonl")


def run() -> list:
    rows = []
    if not os.path.exists(ART):
        return [("roofline_table", 0.0,
                 f"missing {ART}: run `python -m repro.launch.dryrun --probes`")]
    with open(ART) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            if r.get("status") != "ok" or "roofline" not in r:
                continue
            rf = r["roofline"]
            step = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
            ideal = rf["model_flops"] / (r["n_chips"] * 197e12)
            frac = ideal / step if step else 0.0
            rows.append((f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
                         step * 1e6,
                         f"compute={rf['compute_s']:.2e};mem={rf['memory_s']:.2e};"
                         f"coll={rf['collective_s']:.2e};dom={rf['dominant']};"
                         f"roofline_frac={frac:.3f};useful={rf['useful_ratio']:.2f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
