"""Serverless cold-start ladder + fork-tree mass scale-out (DESIGN.md §10).

The paper's headline serverless claim — pre-warmed pods, DRAM pre-loading,
NPU-fork, "scale up to 64 instances in seconds" — measured on the LIVE
serving plane:

* **fork tree** — ``ServingJobEngine.scale_to(n)`` grows 1 SERVING TE to
  4/8 in O(log N) fork rounds (every TE that reaches SERVING in round k
  forks in round k+1, forks within a round concurrent on executor
  threads) vs the serial one-at-a-time baseline
  (``scale_to(n, fan_out=False)``): same registration path, same final
  placement, N-1 rounds. Interleaved best-of-3;
* **cold-start ladder tiers** — single-TE bring-up cost per tier: cold
  (model re-init + construct) vs DRAM-warm (``WarmPool`` host-pinned
  params → ``device_put``, no re-init). Interleaved best-of-3;
* **tier parity** — the same greedy prompts through a cold-constructed,
  a warm-constructed, and a live-forked TE must produce identical tokens.

The model is a bench-scale config (d_model 256 vs the smoke 64), and the
fork-tree phases run ``scale_to(..., pace=ASSET)``: every bring-up job is
held to the MODELED full-size tier cost of a qwen3-8b-class asset
(16 GB over 50 GB/s ICI → 0.32 s/fork, ``scaling.tier_seconds``) — the
same modeled-cost idiom FastScaler uses everywhere else. The CPU sim's
smoke-scale copies finish in microseconds (and this box exposes one
core), so an unpaced wall measures python overhead, not the transfer
regime the tree is built to overlap; the pacing sleep releases the GIL
exactly like a DMA wait, so concurrent forks in one round genuinely
overlap while the serial baseline pays each transfer back-to-back.

    PYTHONPATH=src python benchmarks/bench_scale_out.py [--reps 3]

Also exposes run() -> CSV rows for benchmarks/run.py (key ``scale_out``;
``--json`` → BENCH_scale_out.json).
"""
from __future__ import annotations

import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = \
        (_flags + " --xla_force_host_platform_device_count=8").strip()

import argparse
import time
from dataclasses import replace as _drep

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, smoke_config
from repro.core.scaling import ModelAsset, WarmPool, tier_seconds
from repro.core.serving_plane import ServingJobEngine, TopologySpec
from repro.engine import (EngineConfig, FlowServe, Request, SamplingParams)
from repro.models import get_model

HEAT = (-np.ones((2, 2)), [24, 84], [0.1, 3.0])
SP = SamplingParams(temperature=0.0, max_new_tokens=10, stop_on_eos=False)
# full-size pricing for the paced fork-tree phases: a qwen3-8b-class
# asset (~16 GB bf16) — tier_seconds(ASSET, "fork") ≈ 0.32 s over ICI
ASSET = ModelAsset("qwen3-8b-bench", n_bytes=int(16e9), tp=1)


def _bench_model():
    cfg = _drep(smoke_config(get_config("qwen3-8b")), name="qwen3-8b-bench",
                d_model=256, n_heads=8, head_dim=32, d_ff=512)
    bundle = get_model(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    return bundle, params


def _ecfg(**kw):
    base = dict(n_pages=64, page_size=8, max_batch_tokens=64,
                chunk_size=16, max_decode_batch=4)
    base.update(kw)
    return EngineConfig(**base)


def _plane(bundle, params, warm_pool=None) -> ServingJobEngine:
    return ServingJobEngine(bundle, params, TopologySpec(pd=0, colo=1),
                            heatmap=HEAT[0], prefill_lens=HEAT[1],
                            decode_ratios=HEAT[2], ecfg=_ecfg(),
                            warm_pool=warm_pool)


def _prompts(n, length=14, seed0=0):
    return [[1] + [int(x) for x in
                   np.random.RandomState(seed0 + i).randint(3, 200, length)]
            for i in range(n)]


def _placement(plan, je):
    """Final-placement fingerprint: TE names + owned device windows +
    serving count (the tree and the serial baseline must agree)."""
    return (je.n_serving(), tuple(sorted(je._window_of.items())))


# ------------------------------------------------------------- fork tree
def bench_fork_tree(bundle, params, n: int, reps: int = 3) -> dict:
    """1 SERVING TE → ``n`` via the fork tree vs serial one-at-a-time
    forking, interleaved best-of-``reps``. Each phase builds a FRESH plane
    (jits are per-runner, so every new TE genuinely pays bring-up) and
    scales with ``pace=ASSET`` — each bring-up job is held to the modeled
    full-size fork transfer (0.32 s), which is the wait the tree's
    concurrent rounds overlap and the serial baseline pays N-1 times."""

    def phase(fan_out: bool):
        je = _plane(bundle, params)
        t0 = time.monotonic()
        plan = je.scale_to(n, fan_out=fan_out, pace=ASSET)
        wall = time.monotonic() - t0
        place = _placement(plan, je)
        je.close()
        return wall, len(plan["rounds"]), place, plan["tiers"]

    phase(True)                            # warm the process (imports, BLAS)
    tree_walls, serial_walls = [], []
    places, rounds = [], {}
    for _ in range(reps):
        w, r, p, tiers = phase(True)
        tree_walls.append(w); places.append(p); rounds["tree"] = r
        w, r, p, _ = phase(False)
        serial_walls.append(w); places.append(p); rounds["serial"] = r
    return {
        "n": n,
        "tree_s": min(tree_walls),
        "serial_s": min(serial_walls),
        "speedup": min(serial_walls) / max(1e-9, min(tree_walls)),
        "rounds_tree": rounds["tree"],
        "rounds_serial": rounds["serial"],
        "placement_equal": all(p == places[0] for p in places),
        "tiers": tiers,
    }


def bench_tree_parity(bundle, params, n: int = 4) -> bool:
    """Greedy tokens across a freshly scaled fork tree == the single-TE
    reference (round-robin placement exercises every forked TE)."""
    prompts = _prompts(2 * n)
    ref = FlowServe(bundle, params, _ecfg(), name="ref")
    ids = [ref.add_request(Request(prompt_tokens=list(p), sampling=SP))
           for p in prompts]
    ref_toks = {c.req_id: c.tokens for c in ref.run_to_completion()}
    je = _plane(bundle, params)
    je.policy = "round_robin"              # spread over every forked TE
    je.scale_to(n)
    from repro.core.scheduling import round_robin_scheduler
    je._rr = round_robin_scheduler(je._handles)
    rids = [je.submit(list(p), sampling=SP) for p in prompts]
    comps = {c.req_id: c.tokens for c in je.run_to_completion()}
    used = {e.name for e in je.engines if e.decode_steps > 0}
    je.close()
    return (len(comps) == len(prompts) and len(used) >= n
            and [comps[r] for r in rids] == [ref_toks[i] for i in ids])


# ------------------------------------------------------------- tier costs
def bench_bringup_tiers(bundle, params, reps: int = 3) -> dict:
    """Single-TE bring-up wall per ladder tier, interleaved
    best-of-``reps``: cold = model re-init (fresh ``init_params``) +
    construct; warm = ``WarmPool`` hit → ``device_put`` + construct (no
    re-init). Both land on the same device window and skip jit warmup
    (identical for every tier), so the delta IS the tier cost."""
    pool = WarmPool()
    pool.put(bundle.cfg.name, params)
    ecfg = _ecfg(device_offset=1)

    def cold():
        t0 = time.monotonic()
        p = bundle.init_params(jax.random.PRNGKey(1), jnp.float32)
        te = FlowServe(bundle, p, ecfg, name="cold")
        jax.block_until_ready(te.runner.params)
        return time.monotonic() - t0

    def warm():
        t0 = time.monotonic()
        te = FlowServe.from_warm(bundle, pool.get(bundle.cfg.name), ecfg,
                                 name="warm")
        jax.block_until_ready(te.runner.params)
        return time.monotonic() - t0

    cold(), warm()                         # compile/import warmup
    cold_walls, warm_walls = [], []
    for _ in range(reps):
        cold_walls.append(cold())
        warm_walls.append(warm())
    return {
        "cold_s": min(cold_walls),
        "warm_s": min(warm_walls),
        "speedup": min(cold_walls) / max(1e-9, min(warm_walls)),
        "pool": pool.stats(),
    }


def bench_tier_parity(bundle, params) -> bool:
    """The SAME greedy prompts through a cold-constructed, warm-constructed
    and live-forked TE: tokens must be identical across all three tiers."""
    prompts = _prompts(3, seed0=50)
    pool = WarmPool()
    pool.put(bundle.cfg.name, params)
    src = FlowServe(bundle, params, _ecfg(), name="src")
    tes = {
        "cold": FlowServe(bundle, params, _ecfg(device_offset=1),
                          name="t-cold"),
        "warm": FlowServe.from_warm(bundle, pool.get(bundle.cfg.name),
                                    _ecfg(device_offset=2), name="t-warm"),
        "fork": FlowServe.fork_from(src, _ecfg(device_offset=3),
                                    name="t-fork"),
    }
    toks = {}
    for tier, te in tes.items():
        ids = [te.add_request(Request(prompt_tokens=list(p), sampling=SP))
               for p in prompts]
        comps = {c.req_id: c.tokens for c in te.run_to_completion()}
        toks[tier] = [comps[i] for i in ids]
    return toks["cold"] == toks["warm"] == toks["fork"]


# ------------------------------------------------------------- harness
def run() -> list:
    """CSV rows for benchmarks/run.py: (name, value, derived)."""
    bundle, params = _bench_model()
    rows = []
    parity_tree = bench_tree_parity(bundle, params, n=4)
    parity_tiers = bench_tier_parity(bundle, params)
    for n in (4, 8):
        ft = bench_fork_tree(bundle, params, n)
        rows.append((
            f"scale_out_fork_tree_1to{n}", ft["tree_s"] * 1e6,
            f"tree_s={ft['tree_s']:.2f};serial_s={ft['serial_s']:.2f};"
            f"speedup={ft['speedup']:.2f}x;"
            f"rounds={ft['rounds_tree']}vs{ft['rounds_serial']};"
            f"fork_pace_s={tier_seconds(ASSET, 'fork'):.2f};"
            f"placement_equal={ft['placement_equal']};"
            f"parity={parity_tree}"))
    bt = bench_bringup_tiers(bundle, params)
    rows.append((
        "scale_out_bringup_warm", bt["warm_s"] * 1e6,
        f"warm_s={bt['warm_s']:.3f};cold_s={bt['cold_s']:.3f};"
        f"speedup_vs_cold={bt['speedup']:.2f}x;"
        f"pool_hits={bt['pool']['hits']};parity_tiers={parity_tiers}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--n", type=int, default=8)
    args = ap.parse_args()
    bundle, params = _bench_model()
    print(f"devices={jax.device_count()} model={bundle.cfg.name}")
    for n in (4, args.n) if args.n != 4 else (4,):
        ft = bench_fork_tree(bundle, params, n, reps=args.reps)
        print(f"fork tree 1->{n}: tree {ft['tree_s']:.2f}s "
              f"({ft['rounds_tree']} rounds) vs serial "
              f"{ft['serial_s']:.2f}s ({ft['rounds_serial']} rounds) "
              f"-> {ft['speedup']:.2f}x "
              f"placement_equal={ft['placement_equal']}")
    bt = bench_bringup_tiers(bundle, params, reps=args.reps)
    print(f"bring-up tiers: cold {bt['cold_s'] * 1e3:.0f}ms vs DRAM-warm "
          f"{bt['warm_s'] * 1e3:.0f}ms -> {bt['speedup']:.2f}x")
    print(f"parity: tree={bench_tree_parity(bundle, params)} "
          f"tiers={bench_tier_parity(bundle, params)}")


if __name__ == "__main__":
    main()
