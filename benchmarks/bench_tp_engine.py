"""SPMD tensor-parallel engine benchmark: serve a fixed request batch on a
qwen3 smoke TE at TP ∈ {1,2,4} over simulated host devices and report tok/s,
plus sampler-dispatch accounting — batched sampling costs ONE device
dispatch per decode step where the old per-sequence loop cost B.

    PYTHONPATH=src python benchmarks/bench_tp_engine.py [--arch qwen3-8b]
        [--tp 1,2,4] [--requests 8] [--max-new 32]

Also exposes run() -> CSV rows for benchmarks/run.py (DESIGN.md §6).
"""
from __future__ import annotations

import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = \
        (_flags + " --xla_force_host_platform_device_count=8").strip()

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import EngineConfig, FlowServe, Request, SamplingParams
from repro.models import get_model


def _prompts(n: int, length: int, seed0: int) -> list:
    return [[1] + [int(x) for x in
                   np.random.RandomState(seed0 + i).randint(3, 200, length)]
            for i in range(n)]


def _serve(te: FlowServe, prompts: list, max_new: int) -> int:
    sp = SamplingParams(temperature=0.0, max_new_tokens=max_new,
                        stop_on_eos=False)
    for p in prompts:
        te.add_request(Request(prompt_tokens=p, sampling=sp))
    comps = te.run_to_completion()
    return sum(len(c.tokens) for c in comps)


def bench_tp(arch: str, tp: int, n_requests: int, max_new: int) -> dict:
    bundle = get_model(arch, smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    # prefix cache off: the timed pass must redo full prefills, not RTC hits
    ecfg = EngineConfig(tp=tp, n_pages=256, page_size=8, max_batch_tokens=64,
                        chunk_size=16, max_decode_batch=8,
                        enable_prefix_cache=False)
    te = FlowServe(bundle, params, ecfg)
    _serve(te, _prompts(n_requests, 23, seed0=0), max_new)     # compile warmup
    steps0, disp0 = te.decode_steps, te.sampler_dispatches
    t0 = time.monotonic()
    n_tokens = _serve(te, _prompts(n_requests, 23, seed0=100), max_new)
    dt = time.monotonic() - t0
    steps = te.decode_steps - steps0
    return {"tp": tp, "tok_s": n_tokens / dt, "wall_s": dt,
            "decode_steps": steps,
            "sampler_dispatches": te.sampler_dispatches - disp0,
            "per_seq_dispatches_would_be": n_tokens}


def run() -> list:
    """CSV rows for benchmarks/run.py: (name, value, derived)."""
    rows = []
    tps = []
    for tp in (1, 2, 4):
        if tp <= jax.device_count():
            tps.append(tp)
        else:
            # jax was initialized before this module could force host devices
            # (e.g. another harness module imported first) — say so instead of
            # silently dropping the TP comparison.
            rows.append((f"tp_engine_tp{tp}_SKIPPED", 0.0,
                         f"only {jax.device_count()} devices; run via "
                         "`make bench` or set XLA_FLAGS"))
    for tp in tps:
        r = bench_tp("qwen3-8b", tp, n_requests=8, max_new=32)
        rows.append((f"tp_engine_tp{tp}_tok_s", r["tok_s"],
                     f"dispatches/step="
                     f"{r['sampler_dispatches'] / max(r['decode_steps'], 1):.2f}"
                     f" (per-seq loop would be "
                     f"{r['per_seq_dispatches_would_be'] / max(r['decode_steps'], 1):.1f})"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--tp", default="1,2,4")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()

    print(f"devices={jax.device_count()} arch={args.arch}-smoke "
          f"requests={args.requests} max_new={args.max_new}")
    print(f"{'tp':>4} {'tok/s':>10} {'wall_s':>8} {'decode_steps':>13} "
          f"{'sampler_disp':>13} {'disp/step':>10} {'per-seq would be':>17}")
    for tp_s in args.tp.split(","):
        tp = int(tp_s)
        if tp > jax.device_count():
            print(f"{tp:>4} skipped: only {jax.device_count()} devices")
            continue
        r = bench_tp(args.arch, tp, args.requests, args.max_new)
        print(f"{r['tp']:>4} {r['tok_s']:>10.1f} {r['wall_s']:>8.2f} "
              f"{r['decode_steps']:>13} {r['sampler_dispatches']:>13} "
              f"{r['sampler_dispatches'] / max(r['decode_steps'], 1):>10.2f} "
              f"{r['per_seq_dispatches_would_be'] / max(r['decode_steps'], 1):>17.1f}")


if __name__ == "__main__":
    main()
