"""Batched ragged prefill benchmark (DESIGN.md §12).

legacy  — per-sequence prefill: one batch-1 dispatch per sequence per
          chunk, jit-keyed on the raw (chunk_len, n_pages) pair, first
          token sampled by the decode path.
batched — one-dispatch ragged prefill: the whole step's prefill plan in
          ONE padded pow2-bucketed dispatch (flat token stream, single KV
          scatter per layer, chunk-final logits, first token sampled
          in-dispatch).

Workload is many concurrent prompts / short decode so prefill dispatch
overhead dominates the wall (the regime the paper's host-dispatch budget
targets: a step's prefill plan spans many sequences).
Reports, per TP ∈ {1,2}: prompt tok/s, prefill dispatches per prompt
token (→ 1/step-budget), prefill recompiles in the timed pass (→ 0 after
warmup), TTFT p90, and greedy-token parity legacy vs batched.

    PYTHONPATH=src python benchmarks/bench_prefill_batching.py
        [--arch qwen3-8b] [--tp 1,2] [--requests 16] [--prompt-len 21]
        [--max-new 4]

Also exposes run() -> CSV rows for benchmarks/run.py.
"""
from __future__ import annotations

import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = \
        (_flags + " --xla_force_host_platform_device_count=8").strip()

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import EngineConfig, FlowServe, Request, SamplingParams
from repro.models import get_model


def _prompts(n: int, length: int, seed0: int) -> list:
    # ragged on purpose: lengths stagger ±25% around the nominal so the
    # batched path's padding/bucketing is exercised, not a uniform batch
    out = []
    for i in range(n):
        rs = np.random.RandomState(seed0 + i)
        ln = max(2, length + int(rs.randint(-length // 4, length // 4 + 1)))
        out.append([1] + [int(x) for x in rs.randint(3, 200, ln)])
    return out


def _serve(te: FlowServe, prompts: list, max_new: int):
    sp = SamplingParams(temperature=0.0, max_new_tokens=max_new,
                        stop_on_eos=False)
    for i, p in enumerate(prompts):
        te.add_request(Request(prompt_tokens=p, sampling=sp, req_id=f"q{i}"))
    comps = te.run_to_completion()
    return ({c.req_id: c.tokens for c in comps},
            sorted(c.ttft for c in comps))


def _warm_engine(arch: str, tp: int, n_requests: int, prompt_len: int,
                 max_new: int, batched: bool) -> FlowServe:
    bundle = get_model(arch, smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    ecfg = EngineConfig(tp=tp, n_pages=256, page_size=8, max_batch_tokens=64,
                        chunk_size=8, max_decode_batch=8, max_prefill_seqs=16,
                        enable_prefix_cache=False, batched_prefill=batched)
    te = FlowServe(bundle, params, ecfg)
    # warmup serve passes until the jit set stabilizes (cheaper than
    # te.warmup_prefill()'s full bucket grid, which exists for cold-start
    # production bring-up)
    for w in range(4):
        c0 = te.prefill_jit_compiles + te.jit_compiles
        _serve(te, _prompts(n_requests, prompt_len, seed0=10 * w), max_new)
        if te.prefill_jit_compiles + te.jit_compiles == c0:
            break
    return te


def _timed_pass(te: FlowServe, tp: int, batched: bool, n_requests: int,
                prompt_len: int, max_new: int) -> dict:
    prompts = _prompts(n_requests, prompt_len, seed0=100)
    d0 = dict(pdisp=te.prefill_dispatches, psyncs=te.prefill_syncs,
              pcompiles=te.prefill_jit_compiles)
    t0 = time.monotonic()
    tokens, ttfts = _serve(te, prompts, max_new)
    dt = time.monotonic() - t0
    n_prompt = sum(len(p) for p in prompts)
    return {
        "tp": tp, "batched": batched,
        "prompt_tok_s": n_prompt / dt, "wall_s": dt,
        "prefill_dispatches": te.prefill_dispatches - d0["pdisp"],
        "disp_per_prompt_tok": (te.prefill_dispatches - d0["pdisp"])
        / max(n_prompt, 1),
        "prefill_syncs": te.prefill_syncs - d0["psyncs"],
        "recompiles": te.prefill_jit_compiles - d0["pcompiles"],
        "ttft_p90": ttfts[int(0.9 * (len(ttfts) - 1))],
        "tokens": tokens,
    }


def bench_pair(arch: str, tp: int, n_requests: int, prompt_len: int,
               max_new: int, reps: int = 3) -> dict:
    """legacy vs batched with INTERLEAVED best-of-N timed passes: one pass
    is well under a second of wall on smoke models, so background load
    would otherwise bias whichever variant it happened to land on."""
    te1 = _warm_engine(arch, tp, n_requests, prompt_len, max_new, False)
    te2 = _warm_engine(arch, tp, n_requests, prompt_len, max_new, True)
    v1 = v2 = None
    for _ in range(reps):
        r1 = _timed_pass(te1, tp, False, n_requests, prompt_len, max_new)
        r2 = _timed_pass(te2, tp, True, n_requests, prompt_len, max_new)
        if v1 is None or r1["prompt_tok_s"] > v1["prompt_tok_s"]:
            v1 = r1
        if v2 is None or r2["prompt_tok_s"] > v2["prompt_tok_s"]:
            v2 = r2
    return {"legacy": v1, "batched": v2, "tp": tp,
            "parity": v1["tokens"] == v2["tokens"],
            "speedup": v2["prompt_tok_s"] / max(v1["prompt_tok_s"], 1e-9),
            "ttft_p90_ratio": v1["ttft_p90"] / max(v2["ttft_p90"], 1e-9)}


def run() -> list:
    """CSV rows for benchmarks/run.py: (name, value, derived)."""
    rows = []
    for tp in (1, 2):
        if tp > jax.device_count():
            rows.append((f"prefill_batching_tp{tp}_SKIPPED", 0.0,
                         f"only {jax.device_count()} devices; run via "
                         "`make bench` or set XLA_FLAGS"))
            continue
        r = bench_pair("qwen3-8b", tp, n_requests=16, prompt_len=21, max_new=4)
        v1, v2 = r["legacy"], r["batched"]
        rows.append((f"prefill_batching_tp{tp}_legacy_tok_s",
                     v1["prompt_tok_s"],
                     f"disp/ptok={v1['disp_per_prompt_tok']:.3f} "
                     f"recompiles={v1['recompiles']} "
                     f"ttft_p90={v1['ttft_p90'] * 1e3:.1f}ms"))
        rows.append((f"prefill_batching_tp{tp}_batched_tok_s",
                     v2["prompt_tok_s"],
                     f"disp/ptok={v2['disp_per_prompt_tok']:.3f} "
                     f"recompiles={v2['recompiles']} "
                     f"ttft_p90={v2['ttft_p90'] * 1e3:.1f}ms "
                     f"speedup={r['speedup']:.2f}x "
                     f"ttft_p90_gain={r['ttft_p90_ratio']:.2f}x "
                     f"greedy_parity={r['parity']}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--tp", default="1,2")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=21)
    ap.add_argument("--max-new", type=int, default=4)
    args = ap.parse_args()

    print(f"devices={jax.device_count()} arch={args.arch}-smoke "
          f"requests={args.requests} prompt_len~{args.prompt_len} "
          f"max_new={args.max_new}")
    print(f"{'tp':>4} {'path':>8} {'ptok/s':>10} {'disp/ptok':>10} "
          f"{'recompiles':>11} {'ttft_p90':>10} {'parity':>7} {'speedup':>8}")
    for tp_s in args.tp.split(","):
        tp = int(tp_s)
        if tp > jax.device_count():
            print(f"{tp:>4} skipped: only {jax.device_count()} devices")
            continue
        r = bench_pair(args.arch, tp, args.requests, args.prompt_len,
                       args.max_new)
        for tag in ("legacy", "batched"):
            v = r[tag]
            extra = f"{r['parity']!s:>7} {r['speedup']:>7.2f}x" \
                if tag == "batched" else f"{'-':>7} {'-':>8}"
            print(f"{tp:>4} {tag:>8} {v['prompt_tok_s']:>10.1f} "
                  f"{v['disp_per_prompt_tok']:>10.3f} "
                  f"{v['recompiles']:>11d} "
                  f"{v['ttft_p90'] * 1e3:>8.1f}ms {extra}")


if __name__ == "__main__":
    main()
