"""Decode hot-loop benchmark (the Figure 3 v1→v2 gap, DESIGN.md §8).

v1 — host-driven decode: every step rebuilds the block table on host,
     dispatches decode then a standalone batched sampler, and BLOCKS on
     ``np.asarray(tokens)`` before it can plan the next step.
v2 — NPU-centric decode: sampling fused into the bucketed decode jit,
     persistent device-resident batch metadata, and K-step ``lax.scan``
     horizons whose token block is fetched one horizon late (async).

Reports, per TP ∈ {1,2,4}: tok/s, host dispatches / decode step (→ ≤1/K),
host syncs / step (→ 0), jit recompiles in the timed pass (→ 0 after
warmup), and greedy-token parity v1 vs v2.

    PYTHONPATH=src python benchmarks/bench_decode_hotloop.py [--arch qwen3-8b]
        [--tp 1,2,4] [--requests 8] [--max-new 32] [--horizon 8]

Also exposes run() -> CSV rows for benchmarks/run.py.
"""
from __future__ import annotations

import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = \
        (_flags + " --xla_force_host_platform_device_count=8").strip()

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import EngineConfig, FlowServe, Request, SamplingParams
from repro.models import get_model


def _prompts(n: int, length: int, seed0: int) -> list:
    return [[1] + [int(x) for x in
                   np.random.RandomState(seed0 + i).randint(3, 200, length)]
            for i in range(n)]


def _serve(te: FlowServe, prompts: list, max_new: int) -> dict:
    sp = SamplingParams(temperature=0.0, max_new_tokens=max_new,
                        stop_on_eos=False)
    # ids recycle across passes: each pass's requests are fully released
    for i, p in enumerate(prompts):
        te.add_request(Request(prompt_tokens=p, sampling=sp, req_id=f"q{i}"))
    comps = te.run_to_completion()
    return {c.req_id: c.tokens for c in comps}


def _warm_engine(arch: str, tp: int, n_requests: int, max_new: int,
                 fused: bool, horizon: int) -> FlowServe:
    bundle = get_model(arch, smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    ecfg = EngineConfig(tp=tp, n_pages=256, page_size=8, max_batch_tokens=64,
                        chunk_size=16, max_decode_batch=8,
                        enable_prefix_cache=False, fused_decode=fused,
                        decode_horizon=horizon if fused else 1)
    te = FlowServe(bundle, params, ecfg)
    # warmup serve passes until the jit set stabilizes (cheaper than
    # te.warmup_decode()'s full bucket grid, which exists for cold-start
    # production bring-up): the first pass ramps buckets up and compiles its
    # own trajectory; once a pass compiles nothing, the timed pass repeats it
    for w in range(4):
        c0 = te.jit_compiles
        _serve(te, _prompts(n_requests, 23, seed0=10 * w), max_new)
        if te.jit_compiles == c0:
            break
    return te


def _timed_pass(te: FlowServe, tp: int, fused: bool, horizon: int,
                n_requests: int, max_new: int) -> dict:
    d0 = dict(steps=te.decode_steps, disp=te.host_dispatches,
              syncs=te.host_syncs, compiles=te.jit_compiles,
              sampler=te.sampler_dispatches)
    t0 = time.monotonic()
    tokens = _serve(te, _prompts(n_requests, 23, seed0=100), max_new)
    dt = time.monotonic() - t0
    steps = te.decode_steps - d0["steps"]
    n_tokens = sum(len(t) for t in tokens.values())
    return {
        "tp": tp, "fused": fused, "horizon": horizon if fused else 1,
        "tok_s": n_tokens / dt, "wall_s": dt, "decode_steps": steps,
        "disp_per_step": (te.host_dispatches - d0["disp"]) / max(steps, 1),
        "syncs_per_step": (te.host_syncs - d0["syncs"]) / max(steps, 1),
        "recompiles": te.jit_compiles - d0["compiles"],
        "sampler_dispatches": te.sampler_dispatches - d0["sampler"],
        "tokens": tokens,
    }


def bench_pair(arch: str, tp: int, n_requests: int, max_new: int,
               horizon: int, reps: int = 3) -> dict:
    """v1 vs v2 with INTERLEAVED best-of-N timed passes: one pass is ~0.1s
    of wall on smoke models, so background load would otherwise bias
    whichever variant it happened to land on."""
    te1 = _warm_engine(arch, tp, n_requests, max_new, False, horizon)
    te2 = _warm_engine(arch, tp, n_requests, max_new, True, horizon)
    v1 = v2 = None
    for _ in range(reps):
        r1 = _timed_pass(te1, tp, False, horizon, n_requests, max_new)
        r2 = _timed_pass(te2, tp, True, horizon, n_requests, max_new)
        if v1 is None or r1["tok_s"] > v1["tok_s"]:
            v1 = r1
        if v2 is None or r2["tok_s"] > v2["tok_s"]:
            v2 = r2
    return {"v1": v1, "v2": v2, "tp": tp,
            "parity": v1["tokens"] == v2["tokens"],
            "speedup": v2["tok_s"] / max(v1["tok_s"], 1e-9)}


def run() -> list:
    """CSV rows for benchmarks/run.py: (name, value, derived)."""
    rows = []
    for tp in (1, 2, 4):
        if tp > jax.device_count():
            rows.append((f"decode_hotloop_tp{tp}_SKIPPED", 0.0,
                         f"only {jax.device_count()} devices; run via "
                         "`make bench` or set XLA_FLAGS"))
            continue
        r = bench_pair("qwen3-8b", tp, n_requests=8, max_new=32, horizon=8)
        v1, v2 = r["v1"], r["v2"]
        rows.append((f"decode_hotloop_tp{tp}_v1_tok_s", v1["tok_s"],
                     f"disp/step={v1['disp_per_step']:.2f} "
                     f"syncs/step={v1['syncs_per_step']:.2f} "
                     f"recompiles={v1['recompiles']}"))
        rows.append((f"decode_hotloop_tp{tp}_v2_tok_s", v2["tok_s"],
                     f"K={v2['horizon']} disp/step={v2['disp_per_step']:.2f} "
                     f"syncs/step={v2['syncs_per_step']:.2f} "
                     f"recompiles={v2['recompiles']} "
                     f"speedup={r['speedup']:.2f}x "
                     f"greedy_parity={r['parity']}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--tp", default="1,2,4")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--horizon", type=int, default=8)
    args = ap.parse_args()

    print(f"devices={jax.device_count()} arch={args.arch}-smoke "
          f"requests={args.requests} max_new={args.max_new} "
          f"horizon={args.horizon}")
    print(f"{'tp':>4} {'path':>6} {'tok/s':>10} {'disp/step':>10} "
          f"{'syncs/step':>11} {'recompiles':>11} {'parity':>7} "
          f"{'speedup':>8}")
    for tp_s in args.tp.split(","):
        tp = int(tp_s)
        if tp > jax.device_count():
            print(f"{tp:>4} skipped: only {jax.device_count()} devices")
            continue
        r = bench_pair(args.arch, tp, args.requests, args.max_new,
                       args.horizon)
        for tag in ("v1", "v2"):
            v = r[tag]
            extra = f"{r['parity']!s:>7} {r['speedup']:>7.2f}x" \
                if tag == "v2" else f"{'-':>7} {'-':>8}"
            print(f"{tp:>4} {tag:>6} {v['tok_s']:>10.1f} "
                  f"{v['disp_per_step']:>10.2f} {v['syncs_per_step']:>11.2f} "
                  f"{v['recompiles']:>11d} {extra}")


if __name__ == "__main__":
    main()
