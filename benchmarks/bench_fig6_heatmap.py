"""Figure 6 — PD-disaggregated vs PD-colocated JCT-ratio heatmap.

Full-scale grid from the analytic cost model (T3, 34B TP=4 like the paper)
plus the §5.3.2 combination step (element-wise sum over RPS) and the
stability statistic the paper quotes (>80% of cells sign-consistent)."""
from __future__ import annotations

import numpy as np

from benchmarks.bench_fig4_pd_online import CFG_34B
from repro.core.heatmap import HeatmapStudy
from repro.core.perf_model import TEHardware


def run() -> list:
    hs = HeatmapStudy(CFG_34B, TEHardware(n_chips=4))
    combined = hs.combined()
    stab = hs.stability()
    rows = [("fig6_stability_fraction", 0.0, f"frac={stab:.3f} (paper: >0.80)")]
    pos = float(np.mean(combined > 0))
    rows.append(("fig6_cells_pd_disagg_wins", 0.0, f"frac={pos:.3f}"))
    rows.append(("fig6_max_disagg_advantage", 0.0, f"val={combined.max():.2f}"))
    rows.append(("fig6_max_colo_advantage", 0.0, f"val={-combined.min():.2f}"))
    for i, pl in enumerate(hs.prefill_lens):
        cells = " ".join(f"{combined[i, j]:+.2f}" for j in range(len(hs.decode_ratios)))
        rows.append((f"fig6_row_p{pl}", 0.0, cells))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
