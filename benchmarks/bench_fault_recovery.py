"""Fault injection + recovery on the live serving plane (DESIGN.md §11).

DeepServe's production posture (§7) is detect → contain → replace with
in-flight work recovered, not dropped. This bench kills 1-of-N SERVING
TEs mid-burst with a seeded ``FaultPlan`` and measures what that costs:

* **completion** — 100% of the burst completes; restarted requests are
  counted (``restart_counts``), none lost, none duplicated;
* **recovery time** — wall from crash detection to the fleet repaired
  (``scale_to`` back to N from surviving fork sources) AND every
  restarted request completed;
* **goodput dip** — same burst on an identical no-fault plane; the dip
  is the throughput lost to the kill (re-prefill waste + repair);
* **parity** — greedy tokens vs the no-fault run, for every request:
  a restart re-runs from the PROMPT at temperature 0, so even restarted
  requests must reproduce the reference tokens exactly.

The fault plan's seed picks the victim deterministically
(``FaultPlan.choose_victim``) and is recorded in the JSON row, so a run
is replayable bit-for-bit.

    PYTHONPATH=src python benchmarks/bench_fault_recovery.py [--seed 7]

Also exposes run() -> CSV rows for benchmarks/run.py (key
``fault_recovery``; ``--json`` → BENCH_fault_recovery.json).
"""
from __future__ import annotations

import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = \
        (_flags + " --xla_force_host_platform_device_count=8").strip()

import argparse
import time
from dataclasses import replace as _drep

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, smoke_config
from repro.core.faults import FaultPlan, FaultSpec
from repro.core.serving_plane import ServingJobEngine, TopologySpec
from repro.engine import EngineConfig, SamplingParams
from repro.models import get_model

HEAT = (-np.ones((2, 2)), [24, 84], [0.1, 3.0])
# long enough that the burst is still mid-flight at the kill step
SP = SamplingParams(temperature=0.0, max_new_tokens=24, stop_on_eos=False)
N_TES = 3
N_REQS = 12
KILL_STEP = 3


def _bench_model():
    cfg = _drep(smoke_config(get_config("qwen3-8b")), name="qwen3-8b-bench",
                d_model=256, n_heads=8, head_dim=32, d_ff=512)
    bundle = get_model(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    return bundle, params


def _ecfg(**kw):
    base = dict(n_pages=64, page_size=8, max_batch_tokens=64,
                chunk_size=16, max_decode_batch=4)
    base.update(kw)
    return EngineConfig(**base)


def _plane(bundle, params, fault_plan=None) -> ServingJobEngine:
    return ServingJobEngine(bundle, params, TopologySpec(colo=N_TES),
                            heatmap=HEAT[0], prefill_lens=HEAT[1],
                            decode_ratios=HEAT[2], ecfg=_ecfg(),
                            policy="round_robin", fault_plan=fault_plan)


def _prompts(n, length=14, seed0=0):
    return [[1] + [int(x) for x in
                   np.random.RandomState(seed0 + i).randint(3, 200, length)]
            for i in range(n)]


def _run_burst(je, prompts, repair_to=None, max_steps=20000):
    """Drive one burst to completion; on the first TE failure, repair the
    fleet with ``scale_to(repair_to)``. Returns per-rid tokens (in submit
    order), wall, and the failure/repair timeline."""
    rids = [je.submit(list(p), SP) for p in prompts]
    t0 = time.monotonic()
    t_fail = t_repaired = None
    done_at = {}
    for _ in range(max_steps):
        if not je.has_work():
            break
        comps = je.step()
        now = time.monotonic()
        for c in comps:
            done_at[c.req_id] = now
        if t_fail is None and any(e["kind"] == "te_failure"
                                  for e in je.scale_events):
            t_fail = now
            if repair_to is not None:
                je.scale_to(repair_to)
                t_repaired = time.monotonic()
    wall = time.monotonic() - t0
    toks = {c.req_id: c.tokens for c in je.completions}
    return {"rids": rids, "tokens": [toks.get(r) for r in rids],
            "n_comps": len(je.completions), "wall": wall,
            "t0": t0, "t_fail": t_fail, "t_repaired": t_repaired,
            "done_at": done_at}


def bench_kill_recovery(bundle, params, seed: int) -> dict:
    """Kill 1-of-N mid-burst (seeded victim) vs the identical no-fault
    run. The no-fault run is both the goodput baseline and the
    greedy-token parity oracle."""
    prompts = _prompts(N_REQS)
    base = _plane(bundle, params)
    try:
        ref = _run_burst(base, prompts)
    finally:
        base.close()

    fp = FaultPlan(seed=seed)
    victim = fp.choose_victim([f"te-colo{i}" for i in range(N_TES)])
    fp.add(FaultSpec("te_crash", te=victim, at_step=KILL_STEP))
    je = _plane(bundle, params, fault_plan=fp)
    try:
        got = _run_burst(je, prompts, repair_to=N_TES)
        restarts = je.restart_counts()
        restarted_rids = set(restarts)
        recovery_end = got["t_repaired"] or got["t_fail"]
        for rid in restarted_rids:
            if rid in got["done_at"]:
                recovery_end = max(recovery_end, got["done_at"][rid])
        completed = sum(1 for t in got["tokens"] if t is not None)
        parity = [a == b for a, b in zip(got["tokens"], ref["tokens"])]
        unaffected = [ok for rid, ok in zip(got["rids"], parity)
                      if rid not in restarted_rids]
        out = {
            "seed": seed, "victim": victim, "kill_step": KILL_STEP,
            "fired": fp.fired("te_crash"),
            "n_reqs": N_REQS, "completed": completed,
            "lost": N_REQS - completed,
            "dup": got["n_comps"] - completed,
            "restarts": sum(restarts.values()),
            "n_restarted": len(restarts),
            "recovery_s": (recovery_end - got["t_fail"]
                           if got["t_fail"] is not None else float("nan")),
            "n_serving_after": je.n_serving(),
            "wall_fault_s": got["wall"], "wall_nofault_s": ref["wall"],
            # goodput = tokens/wall over the same token work: the dip is
            # the fraction of no-fault throughput lost to the kill
            "goodput_dip": max(0.0, 1.0 - ref["wall"] / got["wall"]),
            "parity_all": all(parity),
            "parity_unaffected": all(unaffected) if unaffected else True,
        }
    finally:
        je.close()
    return out


# ------------------------------------------------------------- harness
def run() -> list:
    """CSV rows for benchmarks/run.py: (name, value, derived)."""
    bundle, params = _bench_model()
    # warm imports/BLAS so the timed planes measure serving, not first-use
    warm = _plane(bundle, params)
    try:
        _run_burst(warm, _prompts(2, seed0=90))
    finally:
        warm.close()
    r = bench_kill_recovery(bundle, params, seed=7)
    return [(
        f"fault_recovery_kill_1of{N_TES}", r["recovery_s"] * 1e6,
        f"seed={r['seed']};victim={r['victim']};kill_step={r['kill_step']};"
        f"restarts={r['restarts']};"
        f"completed={r['completed']}/{r['n_reqs']};"
        f"lost={r['lost']};dup={r['dup']};"
        f"parity_all={r['parity_all']};"
        f"parity_unaffected={r['parity_unaffected']};"
        f"goodput_dip={r['goodput_dip']:.3f};"
        f"recovery_s={r['recovery_s']:.3f};"
        f"n_serving_after={r['n_serving_after']}")]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()
    bundle, params = _bench_model()
    print(f"devices={jax.device_count()} model={bundle.cfg.name}")
    r = bench_kill_recovery(bundle, params, seed=args.seed)
    print(f"kill 1-of-{N_TES} (seed {r['seed']} -> {r['victim']} at step "
          f"{r['kill_step']}, fired={r['fired']}):")
    print(f"  completed {r['completed']}/{r['n_reqs']} "
          f"(lost={r['lost']} dup={r['dup']}) with {r['restarts']} "
          f"restarts over {r['n_restarted']} requests")
    print(f"  recovery {r['recovery_s']:.3f}s; fleet back to "
          f"{r['n_serving_after']} SERVING")
    print(f"  wall {r['wall_fault_s']:.2f}s vs no-fault "
          f"{r['wall_nofault_s']:.2f}s -> goodput dip "
          f"{r['goodput_dip']:.1%}")
    print(f"  greedy parity: all={r['parity_all']} "
          f"unaffected={r['parity_unaffected']}")


if __name__ == "__main__":
    main()
