"""§5.3.3 — decode-length predict model: bucketed classification accuracy
(paper: 84.9% at 128-token buckets). Tier T1 (real training)."""
from __future__ import annotations

from repro.core import PredictorConfig, synth_trace, train_predictor


def run() -> list:
    cfg = PredictorConfig(steps=400)
    xs, ys, _ = synth_trace(4000, cfg)
    _, acc = train_predictor(cfg, xs, ys)
    return [("predictor_accuracy", 0.0,
             f"acc={acc:.3f} buckets={cfg.n_buckets}x{cfg.bucket_size} "
             f"(paper: 0.849)")]


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
