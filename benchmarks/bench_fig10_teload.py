"""Figure 10 — TE-Load study: DRAM-hit vs DRAM-miss vs theoretical PCIe
bound, and NPU-fork over the scaled-up (ICI/HCCS) vs scaled-out (DCN/RoCE)
fabrics, for three model sizes. Tier T3 + real DistFlow broadcast."""
from __future__ import annotations

from repro.core import DRAMPageCache, ModelAsset, ModelLoader
from repro.engine.distflow import DistFlow


def run() -> list:
    rows = []
    for asset in (ModelAsset("llama3-8b", 16e9, tp=1),
                  ModelAsset("34b", 68e9, tp=4),
                  ModelAsset("llama3-70b", 140e9, tp=8)):
        dram = DRAMPageCache()
        loader = ModelLoader(dram)
        miss = loader.local_load(asset, n_parallel_tes=asset.tp)
        hit = loader.local_load(asset, n_parallel_tes=asset.tp)
        theo = loader.theoretical(asset)
        src = DistFlow("src")
        ici = loader.npu_fork(asset, src, [DistFlow("a")], link="ici")
        dcn = loader.npu_fork(asset, src, [DistFlow("b")], link="dcn")
        rows.append((f"fig10_{asset.name}_dram_miss_s", miss.seconds * 1e6, miss.path))
        rows.append((f"fig10_{asset.name}_dram_hit_s", hit.seconds * 1e6, hit.path))
        rows.append((f"fig10_{asset.name}_theoretical_s", theo * 1e6, "weights/PCIe"))
        rows.append((f"fig10_{asset.name}_npufork_ici_s", ici.seconds * 1e6, ""))
        rows.append((f"fig10_{asset.name}_npufork_dcn_s", dcn.seconds * 1e6, ""))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
