"""Figure 9 — end-to-end scaling latency breakdown before/after the §6
optimizations (pre-warmed pods/TEs, DRAM preload, offline-profiled warmup,
proactive push). Tier T3 (timing model; state machines are real)."""
from __future__ import annotations

from repro.core import DRAMPageCache, FastScaler, ModelAsset


def run() -> list:
    rows = []
    for asset in (ModelAsset("7b", 14e9, tp=1), ModelAsset("34b", 68e9, tp=4),
                  ModelAsset("70b", 140e9, tp=8)):
        scaler = FastScaler(DRAMPageCache())
        scaler.dram.preload(asset)
        before = scaler.scale_one(asset, optimized=False)
        scaler2 = FastScaler(DRAMPageCache())
        scaler2.dram.preload(asset)
        after = scaler2.scale_one(asset, optimized=True)
        for name, ev in (("before", before), ("after", after)):
            detail = ";".join(f"{k}={v:.2f}s" for k, v in ev.steps.items())
            rows.append((f"fig9_{asset.name}_{name}_total_s", ev.total * 1e6,
                         detail))
        rows.append((f"fig9_{asset.name}_speedup", 0.0,
                     f"x={before.total / after.total:.1f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
