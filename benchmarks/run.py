"""Benchmark harness: one module per paper table/figure (DESIGN.md §6).
Prints ``name,us_per_call,derived`` CSV rows; ``--json`` additionally
appends each key's rows to ``BENCH_<key>.json`` (a history list, one entry
per run) so the perf trajectory is tracked in-repo.

    PYTHONPATH=src python -m benchmarks.run [--only fig3,fig6,...] [--json]
"""
from __future__ import annotations

import argparse
import datetime
import importlib
import json
import os
import sys
import time

MODULES = [
    ("table1", "benchmarks.bench_table1_rtc"),
    ("fig3", "benchmarks.bench_fig3_async_sched"),
    ("fig4", "benchmarks.bench_fig4_pd_online"),
    ("fig6", "benchmarks.bench_fig6_heatmap"),
    ("fig7", "benchmarks.bench_fig7_dist_sched"),
    ("predictor", "benchmarks.bench_predictor"),
    ("fig9", "benchmarks.bench_fig9_scaling"),
    ("fig10", "benchmarks.bench_fig10_teload"),
    ("fig11", "benchmarks.bench_fig11_npufork"),
    ("roofline", "benchmarks.bench_roofline"),
    ("tp_engine", "benchmarks.bench_tp_engine"),
    ("pd_migration", "benchmarks.bench_pd_migration"),
    ("decode_hotloop", "benchmarks.bench_decode_hotloop"),
    ("prefill_batching", "benchmarks.bench_prefill_batching"),
    ("serving_plane", "benchmarks.bench_serving_plane"),
    ("scale_out", "benchmarks.bench_scale_out"),
    ("fault_recovery", "benchmarks.bench_fault_recovery"),
]


def _persist_json(key: str, rows: list, wall_s: float, out_dir: str) -> None:
    path = os.path.join(out_dir, f"BENCH_{key}.json")
    history = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                history = json.load(f).get("history", [])
        except (json.JSONDecodeError, OSError):
            history = []
    history.append({
        "ts": datetime.datetime.now(datetime.timezone.utc)
        .isoformat(timespec="seconds"),
        "wall_s": round(wall_s, 3),
        "rows": [{"name": n, "value": v, "derived": d} for n, v, d in rows],
    })
    with open(path, "w") as f:
        json.dump({"key": key, "history": history}, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench keys (e.g. fig3,fig6)")
    ap.add_argument("--json", action="store_true",
                    help="append results to BENCH_<key>.json per bench key")
    ap.add_argument("--json-dir", default=".",
                    help="directory for BENCH_<key>.json (default: cwd)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for key, modname in MODULES:
        if only and key not in only:
            continue
        t0 = time.monotonic()
        try:
            mod = importlib.import_module(modname)
            rows = mod.run()
        except Exception as e:  # noqa: BLE001
            print(f"{key}_ERROR,0,{e!r}")
            failures += 1
            continue
        wall = time.monotonic() - t0
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        print(f"{key}_wall_s,{wall * 1e6:.0f},")
        sys.stdout.flush()
        if args.json:
            _persist_json(key, rows, wall, args.json_dir)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
