"""Benchmark harness: one module per paper table/figure (DESIGN.md §6).
Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only fig3,fig6,...]
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time

MODULES = [
    ("table1", "benchmarks.bench_table1_rtc"),
    ("fig3", "benchmarks.bench_fig3_async_sched"),
    ("fig4", "benchmarks.bench_fig4_pd_online"),
    ("fig6", "benchmarks.bench_fig6_heatmap"),
    ("fig7", "benchmarks.bench_fig7_dist_sched"),
    ("predictor", "benchmarks.bench_predictor"),
    ("fig9", "benchmarks.bench_fig9_scaling"),
    ("fig10", "benchmarks.bench_fig10_teload"),
    ("fig11", "benchmarks.bench_fig11_npufork"),
    ("roofline", "benchmarks.bench_roofline"),
    ("tp_engine", "benchmarks.bench_tp_engine"),
    ("pd_migration", "benchmarks.bench_pd_migration"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench keys (e.g. fig3,fig6)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for key, modname in MODULES:
        if only and key not in only:
            continue
        t0 = time.monotonic()
        try:
            mod = importlib.import_module(modname)
            rows = mod.run()
        except Exception as e:  # noqa: BLE001
            print(f"{key}_ERROR,0,{e!r}")
            failures += 1
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        print(f"{key}_wall_s,{(time.monotonic() - t0) * 1e6:.0f},")
        sys.stdout.flush()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
