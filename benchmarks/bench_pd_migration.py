"""PD KV-migration benchmark (DistFlow v2, DESIGN.md §7).

Per-request migration bytes and simulated seconds for the v1 host-gather
path (numpy round-trip + un-donated full-pool rewrite, kept behind
``host_gather=True``) vs the v2 sharded device path (jit'd sharded gather →
per-link ICI transfer → single donated scatter) at tp ∈ {1,2,4}. The v2 sim
time shows the bytes/tp-per-link speedup; the ``pool_copies`` column shows
the import rewrites the whole pool 2× per request on the v1 path and 0× on
the v2 path.

    PYTHONPATH=src python benchmarks/bench_pd_migration.py [--arch qwen3-8b]
        [--tp 1,2,4] [--requests 4] [--prompt-len 40]

Also exposes run() -> CSV rows for benchmarks/run.py (DESIGN.md §6).
"""
from __future__ import annotations

import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = \
        (_flags + " --xla_force_host_platform_device_count=8").strip()

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import EngineConfig, FlowServe, Request, SamplingParams
from repro.models import get_model


def _prompts(n: int, length: int) -> list:
    return [[1] + [int(x) for x in
                   np.random.RandomState(i).randint(3, 200, length)]
            for i in range(n)]


def _te(bundle, params, mode, tp, offset=0):
    ecfg = EngineConfig(mode=mode, tp=tp, device_offset=offset, n_pages=128,
                        page_size=8, max_batch_tokens=64, chunk_size=16,
                        max_decode_batch=8, enable_prefix_cache=False)
    return FlowServe(bundle, params, ecfg, name=f"te-{mode}-tp{tp}@{offset}")


def bench_path(bundle, params, tp: int, n_requests: int, prompt_len: int,
               host_gather: bool) -> dict:
    pe = _te(bundle, params, "prefill", tp)
    offset = tp if tp > 1 and 2 * tp <= jax.device_count() else 0
    de = _te(bundle, params, "decode", tp, offset)
    pe.distflow.link_cluster([de.distflow])
    sp = SamplingParams(temperature=0.0, max_new_tokens=4, stop_on_eos=False)
    for p in _prompts(n_requests, prompt_len):
        pe.add_request(Request(prompt_tokens=p, sampling=sp))
    ready = []
    while pe.has_work():
        pe.step()
        ready.extend(pe.pop_migratable())
    log0, dlog0 = len(pe.distflow.log), len(de.distflow.log)
    t0 = time.monotonic()
    for rid in ready:
        # overlap=False: the import scatter lands inside the timed region so
        # host and device paths are compared end to end
        pe.migrate_out(rid, de, overlap=False, host_gather=host_gather)
    wall = time.monotonic() - t0
    # both endpoints' logs: the host path charges DtoH (P side), wire, and
    # HtoD (D side); the sharded path is a single per-link wire transfer
    xfers = pe.distflow.log[log0:] + de.distflow.log[dlog0:]
    n_done = len(de.run_to_completion())
    assert n_done == n_requests
    return {
        "path": "host_gather" if host_gather else "sharded",
        "tp": tp,
        "bytes_per_req": sum(x.n_bytes for x in xfers) / n_requests,
        "sim_s_per_req": sum(x.sim_seconds for x in xfers) / n_requests,
        "wall_s_per_req": wall / n_requests,
        "links": max(x.links for x in xfers),
        "pool_copies": de.pool.full_pool_copies / n_requests,
    }


def bench_tp(arch: str, tp: int, n_requests: int, prompt_len: int) -> list:
    bundle = get_model(arch, smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    return [bench_path(bundle, params, tp, n_requests, prompt_len, hg)
            for hg in (True, False)]


def run() -> list:
    """CSV rows for benchmarks/run.py: (name, value, derived)."""
    rows = []
    for tp in (1, 2, 4):
        if tp > jax.device_count():
            rows.append((f"pd_migration_tp{tp}_SKIPPED", 0.0,
                         f"only {jax.device_count()} devices; run via "
                         "`make bench` or set XLA_FLAGS"))
            continue
        host, shard = bench_tp("qwen3-8b", tp, n_requests=4, prompt_len=40)
        speedup = host["sim_s_per_req"] / max(shard["sim_s_per_req"], 1e-12)
        rows.append((
            f"pd_migration_tp{tp}_sharded_sim_us",
            shard["sim_s_per_req"] * 1e6,
            f"host={host['sim_s_per_req'] * 1e6:.1f}us speedup={speedup:.2f}x "
            f"links={shard['links']} bytes/req={shard['bytes_per_req']:.0f} "
            f"pool_copies={shard['pool_copies']:.0f} "
            f"(host path: {host['pool_copies']:.0f})"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--tp", default="1,2,4")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=40)
    args = ap.parse_args()

    print(f"devices={jax.device_count()} arch={args.arch}-smoke "
          f"requests={args.requests} prompt_len={args.prompt_len}")
    print(f"{'tp':>4} {'path':>12} {'KB/req':>8} {'sim_us/req':>11} "
          f"{'wall_ms/req':>12} {'links':>6} {'pool_copies':>12}")
    for tp_s in args.tp.split(","):
        tp = int(tp_s)
        if tp > jax.device_count():
            print(f"{tp:>4} skipped: only {jax.device_count()} devices")
            continue
        for r in bench_tp(args.arch, tp, args.requests, args.prompt_len):
            print(f"{r['tp']:>4} {r['path']:>12} "
                  f"{r['bytes_per_req'] / 1e3:>8.1f} "
                  f"{r['sim_s_per_req'] * 1e6:>11.2f} "
                  f"{r['wall_s_per_req'] * 1e3:>12.2f} {r['links']:>6} "
                  f"{r['pool_copies']:>12.0f}")


if __name__ == "__main__":
    main()
