"""Figure 3 — FLOWSERVE offline decode perf across engine versions.

v1 = synchronous scheduling (scheduler on the critical path each step);
v2 = asynchronous (zero-overhead) scheduling (§4.2);
v3 = v2 + data-structure/sampling optimizations (greedy short-circuit,
     pre-resolved queues).
We run a real CPU engine (smoke model) in pure-decode steady state and
report TPOT and decode throughput. Tier T1 (real execution; absolute
numbers are CPU-scale, the v1→v3 ratios are the reproduced claim).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import EngineConfig, FlowServe, Request, SamplingParams
from repro.models import get_model


def _run(async_sched: bool, n_requests: int = 8, new_tokens: int = 48):
    bundle = get_model("h2o-danube-3-4b", smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    eng = FlowServe(bundle, params, EngineConfig(
        mode="colocated", n_pages=256, page_size=8, max_batch_tokens=64,
        chunk_size=16, max_decode_batch=n_requests, async_sched=async_sched))
    sp = SamplingParams(temperature=0.0, max_new_tokens=new_tokens,
                        stop_on_eos=False)
    prompts = [[1] + [int(x) for x in np.random.RandomState(i).randint(3, 200, 16)]
               for i in range(n_requests)]
    for p in prompts:
        eng.add_request(Request(prompt_tokens=p, sampling=sp))
    # warm up compile caches before timing
    for _ in range(6):
        eng.step()
    t0 = time.monotonic()
    steps0 = eng.steps
    comps = eng.run_to_completion()
    wall = time.monotonic() - t0
    toks = n_requests * new_tokens
    steps = eng.steps - steps0
    return {"tpot_ms": wall / max(steps, 1) * 1e3,
            "tok_per_s": toks / wall,
            "sched_crit_ms": eng.scheduler.sched_time / max(steps, 1) * 1e3}


def run() -> list:
    rows = []
    v1 = _run(async_sched=False)
    v2 = _run(async_sched=True)
    rows.append(("fig3_v1_sync_tpot", v1["tpot_ms"] * 1e3,
                 f"tok_s={v1['tok_per_s']:.1f}"))
    rows.append(("fig3_v2_async_tpot", v2["tpot_ms"] * 1e3,
                 f"tok_s={v2['tok_per_s']:.1f}"))
    rows.append(("fig3_v2_over_v1_throughput", 0.0,
                 f"ratio={v2['tok_per_s'] / v1['tok_per_s']:.3f} "
                 "(~1.0 expected on 1 CPU core: planning cannot physically "
                 "overlap the model step here; the paper's 2x needs an "
                 "accelerator running concurrently with the host)"))
    rows.append(("fig3_sched_plan_time_per_step_v1_us",
                 v1["sched_crit_ms"] * 1e3,
                 "sync: planning sits on the decode critical path"))
    rows.append(("fig3_sched_plan_time_per_step_v2_us",
                 v2["sched_crit_ms"] * 1e3,
                 "async: same work, but prepared while the model step runs "
                 "(plan ready at step start for 100% of steps; outputs "
                 "bit-identical — tests/test_system.py::test_async_vs_sync)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
