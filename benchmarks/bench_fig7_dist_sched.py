"""Figure 7 — distributed scheduling study: RR vs PD-aware vs combined.

Paper setup: 34B TP=4; cluster = 2 PD-colocated TEs + one 1P1D pair;
code-generation trace. Tier T3 sim + the real Algorithm-1 code. Reported:
mean/p90 JCT and TPOT per policy per RPS."""
from __future__ import annotations

import numpy as np

from benchmarks.bench_fig4_pd_online import CFG_34B
from benchmarks.simcluster import SimTE, poisson_trace, run_cluster
from repro.core.heatmap import HeatmapStudy
from repro.core.perf_model import TECostModel, TEHardware
from repro.core.scheduling import (DistributedScheduler, SchedRequest,
                                   TEHandle)


def _cluster():
    cost = TECostModel(CFG_34B, TEHardware(n_chips=4))
    return [SimTE("c0", "colocated", cost), SimTE("c1", "colocated", cost),
            SimTE("pd0", "pd_pair", cost)]


def _codegen_trace(rps, seed=1):
    # code-gen service: medium prompts, long decodes (bimodal)
    def sampler(rng):
        if rng.rand() < 0.5:
            return int(rng.choice([1024, 2048])), int(rng.choice([256, 512]))
        return int(rng.choice([256, 512])), int(rng.choice([32, 64]))
    return poisson_trace(rps, duration=90.0, seed=seed, p_sampler=sampler)


def _policy(tes, name):
    if name == "rr":
        state = {"i": 0}

        def pick(req):
            te = tes[state["i"] % len(tes)]
            state["i"] += 1
            return te
        return pick

    hs = HeatmapStudy(CFG_34B, TEHardware(n_chips=4))
    handles = [TEHandle(te.te_id, te.te_type) for te in tes]
    by_id = {te.te_id: te for te in tes}
    ds = DistributedScheduler(handles, hs.combined(), hs.prefill_lens,
                              hs.decode_ratios)

    def pick_pd(req):
        sreq = SchedRequest(tokens=[0] * req.p_len, predicted_decode=req.d_len)
        if name == "pd":
            sub = ds.pd_aware(sreq, list(ds.tes.values()))
            h = min(sub, key=lambda t: by_id[t.te_id].load())
        else:  # combined
            for h2 in ds.tes.values():
                h2.load = by_id[h2.te_id].load()
            h = ds.dist_sched(sreq)
        ds.commit(sreq, h)
        return by_id[h.te_id]

    return pick_pd


def run() -> list:
    rows = []
    for rps in (0.5, 1.0, 2.0):
        for pol in ("rr", "pd", "combined"):
            tes = _cluster()
            done = run_cluster(tes, _codegen_trace(rps), _policy(tes, pol),
                               horizon=400.0)
            if not done:
                continue
            jct = float(np.mean([r.jct for r in done]))
            p90 = float(np.percentile([r.jct for r in done], 90))
            tpot = float(np.mean([r.tpot for r in done])) * 1e3
            rows.append((f"fig7_{pol}_rps{rps}", jct * 1e6,
                         f"jct={jct:.2f};p90={p90:.2f};tpot_ms={tpot:.1f};n={len(done)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
