"""Table 1 — RTC core-API microbenchmarks (MatchByPrefixToken, MatchByID,
AllocBlocks, AppendBlock, Copy, Populate+Query, Free). Tier T1."""
from __future__ import annotations

import time

import numpy as np

from repro.configs import get_config, smoke_config
from repro.engine.kv_cache import PagedKVPool
from repro.engine.rtc import RelationalTensorCache, RTCCostModel


def _timeit(fn, n=200):
    fn()  # warm
    t0 = time.monotonic()
    for _ in range(n):
        fn()
    return (time.monotonic() - t0) / n * 1e6  # us


def run() -> list:
    cfg = smoke_config(get_config("qwen3-8b"))
    pool = PagedKVPool(cfg, n_pages=512, page_size=8)
    rtc = RelationalTensorCache(pool, RTCCostModel(flops_per_token=1e12))
    rng = np.random.RandomState(0)
    # populate the index with 64 preserved prefixes
    for i in range(64):
        toks = tuple(int(x) for x in rng.randint(3, 200, 32))
        pages = rtc.alloc_blocks(32)
        rtc.preserve_prefix(toks, pages, ctx_id=f"ctx-{i}")
        rtc.free(pages)
    probe = tuple(int(x) for x in rng.randint(3, 200, 32))
    rtc.preserve_prefix(probe, rtc.alloc_blocks(32), ctx_id="probe")

    rows = []
    rows.append(("table1_MatchByPrefixToken_us",
                 _timeit(lambda: rtc.match_by_prefix_token(probe)), "hit"))
    rows.append(("table1_MatchByID_us",
                 _timeit(lambda: rtc.match_by_id("probe")), "hit"))

    def alloc_free():
        pages = rtc.alloc_blocks(64)
        rtc.free(pages)
    rows.append(("table1_AllocBlocks64_Free_us", _timeit(alloc_free, 100), ""))

    def append():
        p = rtc.append_block()
        rtc.free([p])
    rows.append(("table1_AppendBlock_us", _timeit(append, 100), ""))

    entry = rtc.match_by_id("probe").entry
    t0 = time.monotonic()
    rtc.copy_to_dram(entry)
    rows.append(("table1_Copy_npu_to_dram_us",
                 (time.monotonic() - t0) * 1e6, "32 tokens x layers"))
    t0 = time.monotonic()
    ticket = rtc.populate(entry)
    rtc.pump_populates()
    assert ticket is None or rtc.query_populate(ticket.ticket) or True
    rows.append(("table1_Populate_dram_to_npu_us",
                 (time.monotonic() - t0) * 1e6,
                 f"cost_model_fetch={'yes' if ticket else 'recompute'}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
