"""Calibrated cluster simulation (fidelity tier T3) shared by the online
benchmarks (Figures 4 and 7).

Each simulated TE prices work with repro.core.perf_model.TECostModel (the
same model the heatmap study uses); the schedulers under test are the real
repro.core.scheduling policies. Requests arrive Poisson; each TE runs a
simple processor-sharing queue over its decode batch with chunked-prefill
interference for colocated TEs.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.perf_model import TECostModel, TEHardware


@dataclass
class SimRequest:
    rid: int
    arrival: float
    p_len: int
    d_len: int
    start_service: float = -1.0
    first_token: float = -1.0
    finish: float = -1.0

    @property
    def jct(self) -> float:
        return self.finish - self.arrival

    @property
    def ttft(self) -> float:
        return self.first_token - self.arrival

    @property
    def tpot(self) -> float:
        return (self.finish - self.first_token) / max(self.d_len - 1, 1)


class SimTE:
    """One serving endpoint: 'colocated' (chunked prefill shares steps with
    decode) or 'pd_pair' (dedicated prefill stage feeding a decode stage)."""

    def __init__(self, te_id: str, te_type: str, cost: TECostModel,
                 max_batch: int = 16):
        self.te_id = te_id
        self.te_type = te_type
        self.cost = cost
        self.max_batch = max_batch
        self.queue: List[SimRequest] = []      # waiting for prefill
        self.decoding: List[Tuple[SimRequest, int]] = []  # (req, tokens left)
        self.prefill_free_at = 0.0
        self.now = 0.0
        self.done: List[SimRequest] = []

    def submit(self, req: SimRequest) -> None:
        self.queue.append(req)

    def load(self) -> float:
        return (sum(r.p_len + r.d_len for r in self.queue)
                + sum(t for _, t in self.decoding))

    def step(self, dt_target: float) -> float:
        """Advance the TE by roughly dt_target seconds; returns actual dt."""
        # admit prefills
        while self.queue and len(self.decoding) < self.max_batch \
                and self.prefill_free_at <= self.now:
            req = self.queue.pop(0)
            req.start_service = max(self.now, req.arrival)
            t_p = self.cost.prefill_time(req.p_len)
            if self.te_type == "pd_pair":
                # dedicated prefill instance + KV transfer
                t_p += self.cost.kv_bytes_per_token * req.p_len / 50e9
            self.prefill_free_at = req.start_service + t_p
            req.first_token = self.prefill_free_at
            self.decoding.append((req, req.d_len))
        if not self.decoding:
            self.now += dt_target
            return dt_target
        batch = len(self.decoding)
        avg_ctx = int(np.mean([r.p_len + (r.d_len - left)
                               for r, left in self.decoding]))
        step_t = self.cost.decode_step_time(batch, avg_ctx)
        if self.te_type == "colocated" and self.prefill_free_at > self.now:
            step_t *= 1.35  # chunked-prefill interference on decode steps
        steps = max(1, int(dt_target / step_t))
        self.now += steps * step_t
        nxt = []
        for req, left in self.decoding:
            left -= steps
            if left <= 0:
                req.finish = self.now
                self.done.append(req)
            else:
                nxt.append((req, left))
        self.decoding = nxt
        return steps * step_t


def poisson_trace(rps: float, duration: float, seed: int = 0,
                  p_sampler: Optional[Callable] = None) -> List[SimRequest]:
    rng = np.random.RandomState(seed)
    t, out, rid = 0.0, [], 0
    while t < duration:
        t += rng.exponential(1.0 / rps)
        if p_sampler is None:
            p_len = int(rng.choice([512, 1024, 2048, 4096]))
            d_len = max(8, int(p_len * rng.choice([0.05, 0.1, 0.25, 0.5])))
        else:
            p_len, d_len = p_sampler(rng)
        out.append(SimRequest(rid, t, p_len, d_len))
        rid += 1
    return out


def run_cluster(tes: List[SimTE], trace: List[SimRequest],
                pick: Callable[[SimRequest], SimTE],
                horizon: float = 1e9) -> List[SimRequest]:
    """Drive arrivals through `pick` and advance all TEs in lockstep."""
    trace = sorted(trace, key=lambda r: r.arrival)
    i = 0
    now = 0.0
    dt = 0.05
    while i < len(trace) or any(te.decoding or te.queue for te in tes):
        while i < len(trace) and trace[i].arrival <= now:
            pick(trace[i]).submit(trace[i])
            i += 1
        for te in tes:
            te.now = max(te.now, now)
            te.step(dt)
        now += dt
        if now > horizon:
            break
    return [r for te in tes for r in te.done]
