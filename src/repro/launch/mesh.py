"""Production mesh construction.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the pod
axis is a pure data-parallel (DCN-connected) replica dimension.

A FUNCTION, not a module constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def _mk(shape, axes):
    """jax.make_mesh across jax versions: axis_types only exists on newer
    releases (all axes are Auto there anyway, which is also the default)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh for CPU tests (requires forced host device count)."""
    return _mk((data, model), ("data", "model"))


def make_engine_mesh(tp: int, offset: int = 0):
    """1×tp ("data","model") mesh for one FLOWSERVE TE: the TE's NPUs form a
    pure tensor-parallel SPMD group; data parallelism happens across TEs
    (the JE schedules requests over engines), never inside one (DESIGN.md §5).

    ``offset`` places the TE on devices [offset, offset+tp) so co-resident
    TEs (a PD pair, a fork source+target) occupy DISJOINT device windows and
    DistFlow's cross-mesh reshards move between genuinely different device
    sets (DESIGN.md §7).
    """
    n = jax.device_count()
    if offset + tp > n:
        raise RuntimeError(
            f"EngineConfig tp={tp} at device_offset={offset} exceeds the "
            f"visible device count {n}; for simulated-host runs set "
            "XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{max(offset + tp, 8)} before jax initializes")
    if offset == 0:
        return make_host_mesh(data=1, model=tp)
    import numpy as np
    devices = np.asarray(jax.devices()[offset:offset + tp]).reshape(1, tp)
    return jax.sharding.Mesh(devices, ("data", "model"))


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh (pod folds into DP)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
