"""Production mesh construction.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the pod
axis is a pure data-parallel (DCN-connected) replica dimension.

A FUNCTION, not a module constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh for CPU tests (requires forced host device count)."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh (pod folds into DP)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
