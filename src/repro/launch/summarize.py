"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from artifacts/*.jsonl.

    PYTHONPATH=src python -m repro.launch.summarize
"""
from __future__ import annotations

import json
import os
from collections import OrderedDict

ART_DIR = "artifacts"
HBM_BUDGET = 16e9   # v5e per-chip


def load(path):
    cells = OrderedDict()
    if not os.path.exists(path):
        return cells
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            key = (r.get("arch"), r.get("shape"), r.get("mesh"),
                   r.get("dtype", "bf16"))
            cells[key] = r  # last record wins
    return cells


def dryrun_table() -> str:
    cells = load(os.path.join(ART_DIR, "dryrun.jsonl"))
    f32 = {k[:3]: v for k, v in cells.items() if k[3] == "f32"}
    out = ["| arch | shape | mesh | status | compile_s | peak GB/chip "
           "(bf16-emul UB) | TPU est GB/chip | fits 16GB | collectives (MB, "
           "ag/ar/rs/a2a/cp) |",
           "|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape, mesh, dtype), r in cells.items():
        if dtype != "bf16":
            continue
        if r["status"] == "skipped":
            out.append(f"| {arch} | {shape} | {mesh} | skipped — "
                       f"{r['reason'][:40]} | | | | | |")
            continue
        if r["status"] == "error":
            out.append(f"| {arch} | {shape} | {mesh} | ERROR {r['error'][:40]}"
                       f" | | | | | |")
            continue
        peak = r["peak_device_bytes"] / 1e9
        est = peak
        note = ""
        fkey = (arch, shape, mesh)
        if fkey in f32 and f32[fkey].get("status") == "ok":
            est = f32[fkey]["peak_device_bytes"] / 2e9
            note = " (f32/2)"
        coll = r.get("full_artifact", {}).get("collectives", {})
        cm = "/".join(f"{coll.get(k, 0)/1e6:.0f}" for k in
                      ("all-gather", "all-reduce", "reduce-scatter",
                       "all-to-all", "collective-permute"))
        fits = "yes" if est <= HBM_BUDGET / 1e9 else "NO"
        out.append(f"| {arch} | {shape} | {mesh} | ok | {r['compile_s']} | "
                   f"{peak:.2f} | {est:.2f}{note} | {fits} | {cm} |")
    return "\n".join(out)


def roofline_table() -> str:
    cells = load(os.path.join(ART_DIR, "dryrun_probes.jsonl"))
    out = ["| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| MODEL_FLOPS | useful ratio | roofline frac | move-the-needle |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape, mesh, dtype), r in cells.items():
        if r.get("status") != "ok" or "roofline" not in r or mesh != "single":
            continue
        rf = r["roofline"]
        step = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        ideal = rf["model_flops"] / (r["n_chips"] * 197e12)
        frac = ideal / step if step else 0.0
        hint = {
            "compute": "cut non-useful FLOPs (remat/attention masking)",
            "memory": "shrink bytes touched (dtype, fusion, cache layout)",
            "collective": "re-shard to cut wire bytes / overlap collectives",
        }[rf["dominant"]]
        out.append(f"| {arch} | {shape} | {rf['compute_s']:.2e} | "
                   f"{rf['memory_s']:.2e} | {rf['collective_s']:.2e} | "
                   f"{rf['dominant']} | {rf['model_flops']:.2e} | "
                   f"{rf['useful_ratio']:.2f} | {frac:.3f} | {hint} |")
    return "\n".join(out)


def main() -> None:
    print("## §Dry-run\n")
    print(dryrun_table())
    print("\n## §Roofline\n")
    print(roofline_table())


if __name__ == "__main__":
    main()
