"""Inject generated tables into EXPERIMENTS.md placeholders.

    PYTHONPATH=src python -m repro.launch.finalize_experiments
"""
from __future__ import annotations

import json
import os

from repro.launch.summarize import dryrun_table, load, roofline_table

EXP = "EXPERIMENTS.md"


def hillclimb_rows() -> dict:
    out = {}
    path = os.path.join("artifacts", "hillclimb.jsonl")
    if not os.path.exists(path):
        return out
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            if r.get("status") == "ok" and "roofline" in r:
                out[r["variant"]] = r
    return out


def fmt_variant(r, base) -> str:
    rf, bf = r["roofline"], base["roofline"]
    step_b = max(bf["compute_s"], bf["memory_s"], bf["collective_s"])
    step_o = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
    ideal = rf["model_flops"] / (r["n_chips"] * 197e12)
    return (f"compute {bf['compute_s']:.2e}→{rf['compute_s']:.2e}, "
            f"memory {bf['memory_s']:.2e}→{rf['memory_s']:.2e}, "
            f"collective {bf['collective_s']:.2e}→{rf['collective_s']:.2e}; "
            f"step {step_b:.2e}→{step_o:.2e} s (×{step_b/step_o:.2f}); "
            f"roofline frac {ideal/step_b:.4f}→{ideal/step_o:.4f}")


def main() -> None:
    base = load(os.path.join("artifacts", "dryrun_probes.jsonl"))
    hc = hillclimb_rows()

    def baseline(arch, shape):
        return base[(arch, shape, "single", "f32")]

    text = open(EXP).read()
    text = text.replace("<!-- DRYRUN_TABLE -->", dryrun_table())
    text = text.replace("<!-- ROOFLINE_TABLE -->", roofline_table())
    text = text.replace("<!-- PERF_LOG -->",
                        "(full log below — three focus cells + extras)")

    def result_block(names_archs):
        lines = []
        for name, arch, shape in names_archs:
            if name not in hc:
                lines.append(f"* `{name}`: (not recorded)")
                continue
            lines.append(f"* **{name}** — "
                         f"{fmt_variant(hc[name], baseline(arch, shape))}")
        return "\n".join(lines)

    text = text.replace("<!-- CELL_A_RESULT -->", result_block([
        ("mixtral_decode_windowed", "mixtral-8x7b", "decode_32k"),
        ("mixtral_decode_ring", "mixtral-8x7b", "decode_32k"),
        ("mixtral_long500k_windowed", "mixtral-8x7b", "long_500k"),
        ("mixtral_long500k_ring", "mixtral-8x7b", "long_500k"),
    ]))
    text = text.replace("<!-- CELL_B_RESULT -->", result_block([
        ("granite_prefill_cp", "granite-moe-3b-a800m", "prefill_32k"),
        ("granite_prefill_cp_cshard", "granite-moe-3b-a800m", "prefill_32k"),
    ]))
    text = text.replace("<!-- CELL_C_RESULT -->", result_block([
        ("rwkv6_train_zero2", "rwkv6-1.6b", "train_4k"),
        ("rwkv6_train_dp256", "rwkv6-1.6b", "train_4k"),
    ]))
    text = text.replace("<!-- EXTRAS_RESULT -->", result_block([
        ("danube_prefill_banded", "h2o-danube-3-4b", "prefill_32k"),
        ("mixtral_prefill_banded", "mixtral-8x7b", "prefill_32k"),
        ("rgemma_prefill_cp", "recurrentgemma-2b", "prefill_32k"),
    ]))
    open(EXP, "w").write(text)
    print("EXPERIMENTS.md finalized")


if __name__ == "__main__":
    main()
