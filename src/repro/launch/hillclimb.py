import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: re-probe the three focus cells under optimized
configurations and append (variant-tagged) records to
artifacts/hillclimb.jsonl. Baselines live in artifacts/dryrun_probes.jsonl.

    PYTHONPATH=src python -m repro.launch.hillclimb [--only mixtral_windowed ...]
"""
import argparse
import json
import traceback

from repro.launch import dryrun as DR

# (variant name, arch, shape, opt_flags)
VARIANTS = [
    # Cell A — mixtral decode_32k (paper-representative: SuperPod MoE decode)
    ("mixtral_decode_windowed", "mixtral-8x7b", "decode_32k",
     {"perf": {"windowed_decode": True}}),
    ("mixtral_long500k_windowed", "mixtral-8x7b", "long_500k",
     {"perf": {"windowed_decode": True}}),
    # Cell B — granite prefill_32k (worst roofline fraction)
    ("granite_prefill_cp", "granite-moe-3b-a800m", "prefill_32k",
     {"cp_attention": True}),
    # Cell C — rwkv6 train_4k (most collective-bound)
    ("rwkv6_train_zero2", "rwkv6-1.6b", "train_4k", {"fsdp": False}),
    # iteration 2 (windowed-gather + SP-recurrent hypotheses refuted):
    ("mixtral_decode_ring", "mixtral-8x7b", "decode_32k",
     {"perf": {"ring_buffer_decode": True}}),
    ("mixtral_long500k_ring", "mixtral-8x7b", "long_500k",
     {"perf": {"ring_buffer_decode": True}}),
    ("rwkv6_train_dp256", "rwkv6-1.6b", "train_4k",
     {"fsdp": False, "act": "batch_all"}),
    ("granite_prefill_cp_cshard", "granite-moe-3b-a800m", "prefill_32k",
     {"cp_attention": True, "moe_cshard": True}),
    # extras beyond the required three
    ("danube_prefill_banded", "h2o-danube-3-4b", "prefill_32k",
     {"perf": {"banded_swa_prefill": True}}),
    ("mixtral_prefill_banded", "mixtral-8x7b", "prefill_32k",
     {"perf": {"banded_swa_prefill": True}}),
    ("rgemma_prefill_cp", "recurrentgemma-2b", "prefill_32k",
     {"cp_attention": True}),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--out", default="artifacts/hillclimb.jsonl")
    ap.add_argument("--dtype", default="f32", choices=["bf16", "f32"])
    args = ap.parse_args()
    DR.set_dtype(args.dtype)

    for name, arch, shape, flags in VARIANTS:
        if args.only and name not in args.only:
            continue
        print(f"[hillclimb] {name}: {arch} × {shape} flags={flags}", flush=True)
        try:
            rec = DR.compile_cell(arch, shape, multi_pod=False,
                                  run_probes=True, opt_flags=flags)
        except Exception as e:  # noqa: BLE001
            rec = {"status": "error", "error": repr(e),
                   "trace": traceback.format_exc()[-1500:]}
        rec["variant"] = name
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
        rf = rec.get("roofline", {})
        print(f"[hillclimb]   -> {rec.get('status')} "
              f"dom={rf.get('dominant')} comp={rf.get('compute_s', 0):.3e} "
              f"mem={rf.get('memory_s', 0):.3e} "
              f"coll={rf.get('collective_s', 0):.3e}", flush=True)


if __name__ == "__main__":
    main()
