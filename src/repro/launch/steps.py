"""Compiled step functions for the dry-run / launchers.

One builder per step kind; each returns ``(fn, example_inputs)`` where
example_inputs are ShapeDtypeStructs (nothing is allocated):

  * build_train_step   — fwd(remat, scan) → grads → AdamW
  * build_prefill_step — prompt → (last logits, full KV cache) [scan towers]
  * build_decode_step  — serving.decode_step (one token, cache in/out)

The prefill builders here produce the cache *without* scatter writes
(from-scratch prefill: cache = stacked fresh K/V), which is both the
efficient artifact and what the PD-disaggregated prefill TE ships to
decode TEs.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import layers as L
from repro.models import serving as S
from repro.models import transformer as T
from repro.models.model_factory import ModelBundle, cross_entropy, get_model
from repro.training.optimizer import OptimizerConfig, adamw_update, init_opt_state

FLASH_CHUNK = 1024


def example_batch(cfg: ModelConfig, shape: ShapeConfig, dtype=jnp.bfloat16
                  ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    out: Dict[str, Any] = {}
    if shape.kind == "train":
        out["tokens"] = sds((b, s), jnp.int32)
        out["targets"] = sds((b, s), jnp.int32)
        out["mask"] = sds((b, s), jnp.float32)
    elif shape.kind == "prefill":
        out["tokens"] = sds((b, s), jnp.int32)
    else:  # decode
        out["token"] = sds((b,), jnp.int32)
    if cfg.vision is not None and shape.kind != "decode":
        out["vision_embeds"] = sds((b, cfg.vision.n_patches, cfg.d_model), dtype)
    if cfg.encoder is not None and shape.kind != "decode":
        out["frames"] = sds((b, cfg.encoder.n_frames, cfg.d_model), dtype)
    return out


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------


def default_microbatches(cfg: ModelConfig) -> int:
    """Gradient-accumulation factor for the 1M-token train_4k step.
    MoE dispatch (top_k·capacity_factor ≈ 2.5× token duplication) and VLM
    cross-attention memories need smaller live activation sets."""
    if cfg.vision is not None:
        return 8      # cross-attn score tensors over 1601 patches
    if cfg.moe is not None:
        return 4
    return 1


def build_train_step(cfg: ModelConfig, opt_cfg: OptimizerConfig = OptimizerConfig(),
                     remat: bool = True, attn_impl: str = "flash",
                     microbatches: int = 1):
    def loss_fn(params, tokens, targets, mask, extra):
        # remat is applied per layer inside the towers (scan-body
        # checkpointing), NOT around the whole forward — wrapping the whole
        # forward still saves every scan iteration's residuals.
        logits = T.forward(cfg, params, tokens, attn_impl=attn_impl,
                           scan_layers=True, remat=remat, **extra)
        return cross_entropy(logits, targets, mask, cfg.vocab_size)

    def train_step(params, opt_state, tokens, targets, mask, extra):
        if microbatches > 1:
            def resh(a):
                return a.reshape((microbatches, a.shape[0] // microbatches)
                                 + a.shape[1:])

            mb = (resh(tokens), resh(targets), resh(mask),
                  {k: resh(v) for k, v in extra.items()})

            def body(carry, xs):
                g_acc, l_acc = carry
                t, y, m, ex = xs
                l, g = jax.value_and_grad(loss_fn)(params, t, y, m, ex)
                g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                 g_acc, g)
                return (g, l_acc + l), None

            zero = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(body, (zero, 0.0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, tokens, targets,
                                                      mask, extra)
        params, opt_state, metrics = adamw_update(opt_cfg, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# Prefill (from scratch, scan towers, cache as stacked fresh K/V)
# ---------------------------------------------------------------------------


def build_prefill_step(cfg: ModelConfig, attn_impl: str = "flash"):
    if cfg.attn_kind == "rwkv":
        return _prefill_rwkv(cfg)
    if cfg.attn_kind == "hybrid_rglru":
        return _prefill_hybrid(cfg, attn_impl)
    if cfg.encoder is not None:
        return _prefill_encdec(cfg, attn_impl)
    if cfg.vision is not None:
        return _prefill_vlm(cfg, attn_impl)
    return _prefill_dense(cfg, attn_impl)


def _attn_for_prefill(cfg, q, k, v, positions, win, attn_impl):
    from repro.models import actsharding as AS
    from repro.models import perf_flags as PF
    s = q.shape[1]
    if attn_impl == "naive" or s <= 2048:
        mask = L.causal_mask(positions, positions)
        mask &= positions[:, None, :] > (positions[:, :, None] - win)
        return L.attention(q, k, v, mask, cfg.attn_logit_softcap)
    q = AS.constrain_tag(q, "attn_q_seq")  # context-parallel rows (§Perf)
    # banded SWA path: needs a static window shared by every scanned layer
    if (PF.get().banded_swa_prefill and cfg.attn_kind == "swa"
            and cfg.window is not None and cfg.window + 1024 < s):
        o = L.banded_swa_attention(q, k, v, cfg.window,
                                   softcap=cfg.attn_logit_softcap)
    else:
        o = L.flash_attention(q, k, v, positions, positions, window=win,
                              softcap=cfg.attn_logit_softcap, chunk=FLASH_CHUNK)
    return AS.constrain_tag(o, "attn_q_seq")


def _block_with_kv(cfg, p, x, positions, win, attn_impl):
    """Pre-norm attention block that also returns this layer's fresh K/V."""
    h = L.apply_norm(x, p["ln1"], cfg.norm)
    q, k_new, v_new = L.attn_qkv(p["attn"], h, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.head_dim, positions, cfg.rope_theta,
                                 cfg.qk_norm)
    o = _attn_for_prefill(cfg, q, k_new, v_new, positions, win, attn_impl)
    x = x + S._post_attn(cfg, p, L.attn_out(p["attn"], o))
    h = L.apply_norm(x, p["ln2"], cfg.norm)
    if "moe" in p:
        from repro.models import moe as M
        m = M.moe_apply(p["moe"], h, cfg.moe, cfg.mlp_act, groups=T._moe_groups(h))
    else:
        m = L.mlp_apply(p["mlp"], h, cfg.mlp_act)
    if cfg.post_norms:
        m = L.apply_norm(m, p["ln2_post"], cfg.norm)
    return x + m, k_new, v_new


def _prefill_dense(cfg, attn_impl):
    def prefill(params, tokens, extra):
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        x = T.embed(cfg, params, tokens)
        wins = T.window_schedule(cfg)

        def body(h, xs):
            p, w = xs
            h, k, v = _block_with_kv(cfg, p, h, positions, w, attn_impl)
            return h, (k, v)

        x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], wins))
        logits = T.unembed(cfg, params, x[:, -1:])[:, 0]
        cache = {"k": ks, "v": vs,
                 "length": jnp.full((b,), s, jnp.int32)}
        return logits, cache

    return prefill


def _prefill_vlm(cfg, attn_impl):
    every = cfg.vision.cross_attn_every
    n_groups = cfg.n_layers // every

    def prefill(params, tokens, extra):
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        x = T.embed(cfg, params, tokens)
        wins = T.window_schedule(cfg).reshape(n_groups, every)
        grouped = jax.tree.map(
            lambda a: a.reshape(n_groups, every, *a.shape[1:]), params["blocks"])
        vis = extra["vision_embeds"]

        def group_body(h, xs):
            pg, wg, pc = xs

            def inner(h2, xs2):
                p, w = xs2
                h2, k, v = _block_with_kv(cfg, p, h2, positions, w, attn_impl)
                return h2, (k, v)

            h, (ks, vs) = jax.lax.scan(inner, h, (pg, wg))
            mk, mv = T.memory_kv(cfg, pc["attn"], vis)
            h = T.cross_block_apply(cfg, pc, h, mk, mv, gated=True)
            return h, (ks, vs, mk, mv)

        x, (ks, vs, cks, cvs) = jax.lax.scan(group_body, x,
                                             (grouped, wins, params["cross_blocks"]))
        logits = T.unembed(cfg, params, x[:, -1:])[:, 0]
        cache = {"k": ks.reshape(cfg.n_layers, b, s, cfg.n_kv_heads, cfg.head_dim),
                 "v": vs.reshape(cfg.n_layers, b, s, cfg.n_kv_heads, cfg.head_dim),
                 "cross_k": cks, "cross_v": cvs,
                 "length": jnp.full((b,), s, jnp.int32)}
        return logits, cache

    return prefill


def _prefill_encdec(cfg, attn_impl):
    def prefill(params, tokens, extra):
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        mem = T.encode(cfg, params, extra["frames"], attn_impl="flash")
        x = T.embed(cfg, params, tokens)

        def body(h, xs):
            p, pc = xs
            h, k, v = _block_with_kv(cfg, p, h, positions,
                                     jnp.int32(T.GLOBAL_WINDOW), attn_impl)
            mk, mv = T.memory_kv(cfg, pc["attn"], mem)
            h = T.cross_block_apply(cfg, pc, h, mk, mv, gated=False)
            return h, (k, v, mk, mv)

        x, (ks, vs, cks, cvs) = jax.lax.scan(body, x, (params["blocks"],
                                                       params["cross_blocks"]))
        logits = T.unembed(cfg, params, x[:, -1:])[:, 0]
        cache = {"k": ks, "v": vs, "cross_k": cks, "cross_v": cvs,
                 "length": jnp.full((b,), s, jnp.int32)}
        return logits, cache

    return prefill


def _prefill_rwkv(cfg):
    def prefill(params, tokens, extra):
        b, s = tokens.shape
        x = T.embed(cfg, params, tokens)
        h = cfg.d_model // cfg.rwkv.head_dim
        z_state = jnp.zeros((b, h, cfg.rwkv.head_dim, cfg.rwkv.head_dim), jnp.float32)
        z_last = jnp.zeros((b, cfg.d_model), x.dtype)

        def body(hid, p):
            hid, st, ltm, lcm = T.rwkv_block_apply(cfg, p, hid, z_state, z_last,
                                                   z_last, chunked=True)
            return hid, (st, ltm, lcm)

        x, (st, ltm, lcm) = jax.lax.scan(body, x, params["blocks"])
        logits = T.unembed(cfg, params, x[:, -1:])[:, 0]
        cache = {"state": st, "last_tm": ltm, "last_cm": lcm,
                 "length": jnp.full((b,), s, jnp.int32)}
        return logits, cache

    return prefill


def _prefill_hybrid(cfg, attn_impl):
    def prefill(params, tokens, extra):
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        x = T.embed(cfg, params, tokens)
        w = cfg.rglru.lru_width
        cw = cfg.rglru.conv1d_width
        ks, vs, hs, convs = [], [], [], []
        ri = ai = 0
        for kind in cfg.layer_kinds():
            if kind == "rglru":
                p = params["rglru_blocks"][ri]
                x, h_i, c_i = T.rglru_block_apply(
                    cfg, p, x, jnp.zeros((b, w), jnp.float32),
                    jnp.zeros((b, cw - 1, w), x.dtype))
                hs.append(h_i)
                convs.append(c_i)
                ri += 1
            else:
                p = params["attn_blocks"][ai]
                x, k, v = _block_with_kv(cfg, p, x, positions,
                                         jnp.int32(cfg.window or T.GLOBAL_WINDOW),
                                         attn_impl)
                ks.append(k)
                vs.append(v)
                ai += 1
        logits = T.unembed(cfg, params, x[:, -1:])[:, 0]
        cache = {"k": jnp.stack(ks), "v": jnp.stack(vs),
                 "h": jnp.stack(hs), "conv": jnp.stack(convs),
                 "length": jnp.full((b,), s, jnp.int32)}
        return logits, cache

    return prefill


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def build_decode_step(cfg: ModelConfig):
    def decode(params, token, cache):
        return S.decode_step(cfg, params, token, cache)

    return decode


def decode_cache_struct(cfg: ModelConfig, shape: ShapeConfig,
                        dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree of a decode cache at this shape's context."""
    return jax.eval_shape(
        lambda: S.init_cache(cfg, shape.global_batch, shape.seq_len, dtype))
