"""Serving launcher: wire FLOWSERVE TEs + a model-serving JE + the
autoscaler into a runnable deployment (CPU: smoke-config models).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b \
        --mode colocated --requests 16

    # the live serving plane (DESIGN.md §9): Algorithm 1 over a real fleet
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b \
        --topology pd=1,colo=1 --policy dist_sched --requests 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, smoke_config
from repro.core.heatmap import HeatmapStudy
from repro.core.predictor import (DecodeLengthPredictor, PredictorConfig,
                                  synth_trace, train_predictor)
from repro.core.scheduling import (DistributedScheduler, SchedRequest,
                                   TEHandle)
from repro.engine import EngineConfig, FlowServe, Request, SamplingParams
from repro.engine.tokenizer import ByteTokenizer
from repro.models import get_model


def build_te(bundle, params, mode: str, name: str, tp: int = 1,
             horizon: int = 8, fused: bool = True) -> FlowServe:
    ecfg = EngineConfig(mode=mode, tp=tp, n_pages=256, page_size=8, n_slots=8,
                        max_len=256, max_batch_tokens=64, chunk_size=16,
                        max_decode_batch=8, fused_decode=fused,
                        decode_horizon=horizon)
    return FlowServe(bundle, params, ecfg, name=name)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--mode", default="colocated",
                    choices=["colocated", "pd", "scheduled"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--tp", type=int, default=1,
                    help="devices per TE (SPMD tensor parallelism; simulated "
                         "hosts need XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N)")
    ap.add_argument("--horizon", type=int, default=8,
                    help="max fused multi-step decode horizon K "
                         "(DESIGN.md §8; 1 disables multi-step)")
    ap.add_argument("--no-fused-decode", action="store_true",
                    help="legacy v1 decode path (per-step host block tables "
                         "+ standalone sampler dispatch)")
    ap.add_argument("--topology", default=None,
                    help="live serving plane (DESIGN.md §9): fleet spec "
                         "'pd=N,colo=N' — N PD-disaggregated 1P+1D pairs "
                         "plus N PD-colocated TEs — or 'pd=NpXd,colo=N' "
                         "for an M:N group whose N prefill TEs feed X "
                         "decode TEs (§4.6; tp/horizon flags apply per "
                         "TE). Overrides --mode.")
    ap.add_argument("--policy", default="dist_sched",
                    choices=["dist_sched", "round_robin"],
                    help="JE placement policy for --topology (Algorithm 1 "
                         "vs the degenerate round-robin baseline)")
    ap.add_argument("--scale-to", type=int, default=0,
                    help="with --topology: mass scale-out to N SERVING TEs "
                         "through the cold-start ladder before serving "
                         "(DESIGN.md §10) — O(log N) fork rounds, "
                         "DRAM-warm remainder, cold fallback")
    ap.add_argument("--fleet-threads", type=int, default=0,
                    help="per-TE executor threads for --topology "
                         "(core/fleet.py): >1 steps fleet units on pinned "
                         "worker threads so engines overlap wall-clock "
                         "work; 0/1 = serial stepping")
    args = ap.parse_args()
    if args.tp > 1:
        print(f"TE mesh: 1x{args.tp} over {jax.device_count()} visible devices")

    bundle = get_model(args.arch, smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    tok = ByteTokenizer()
    sp = SamplingParams(temperature=0.0, max_new_tokens=args.max_new,
                        stop_on_eos=False)
    prompts = [f"request {i}: explain serverless llm serving" for i in range(args.requests)]

    if args.topology:
        from repro.core.scaling import (DrainTrigger, DRAMPageCache,
                                        FastScaler, LoadSpreadTrigger,
                                        WarmPool)
        from repro.core.serving_plane import ServingJobEngine, TopologySpec
        topo = TopologySpec.parse(args.topology)
        if args.tp > 1:
            if topo.tp > 1 and topo.tp != args.tp:
                raise SystemExit(f"conflicting tp: --tp {args.tp} vs "
                                 f"--topology ...,tp={topo.tp}")
            topo.tp = args.tp
        cfg_full = get_config(args.arch)
        hs = HeatmapStudy(cfg_full)
        ecfg = EngineConfig(tp=topo.tp, n_pages=256, page_size=8,
                            max_batch_tokens=64, chunk_size=16,
                            max_decode_batch=8, decode_horizon=args.horizon,
                            fused_decode=not args.no_fused_decode)
        warm = WarmPool()
        je = ServingJobEngine(bundle, params, topo, heatmap=hs.combined(),
                              prefill_lens=hs.prefill_lens,
                              decode_ratios=hs.decode_ratios,
                              policy=args.policy, ecfg=ecfg,
                              scaler=FastScaler(DRAMPageCache(), warm=warm),
                              trigger=LoadSpreadTrigger(),
                              drain_trigger=DrainTrigger(),
                              warm_pool=warm,
                              fleet_threads=args.fleet_threads)
        if args.scale_to > je.n_serving():
            plan = je.scale_to(args.scale_to)
            tiers = " ".join(f"{k}={v}" for k, v in plan["tiers"].items()
                             if v)
            print(f"scale_to({args.scale_to}): {len(plan['rounds'])} rounds "
                  f"in {plan['wall_s']:.2f}s [{tiers}] "
                  f"serving={plan['n_serving']}")
            for r in plan["rounds"]:
                print(f"  round {r['round']}: +{len(r['tes'])} TEs "
                      f"({r['wall_s']:.2f}s) from {r['sources'] or ['-']}")
        t0 = time.monotonic()
        for p in prompts:
            je.submit(tok.encode(p), sampling=sp)
        comps = je.run_to_completion()
        dt = time.monotonic() - t0
        n_tok = sum(len(c.tokens) for c in comps)
        ttft = sum(c.ttft for c in comps) / max(1, len(comps))
        tpot = sum(c.tpot for c in comps) / max(1, len(comps))
        print(f"serving plane [{args.policy}] topology={args.topology} "
              f"fleet_threads={args.fleet_threads}: "
              f"{len(comps)} completions in {dt:.2f}s ({n_tok/dt:.1f} tok/s) "
              f"ttft={ttft*1e3:.0f}ms tpot={tpot*1e3:.1f}ms")
        print(f"  decisions={je.scheduler.decisions} "
              f"scale_events={len(je.scale_events)}")
        for te_id, m in je.fleet_metrics().items():
            extra = (f" {m['n_prefill']}P:{m['n_decode']}D"
                     if m["type"] == "pd_pair" else "")
            print(f"  {te_id}: type={m['type']} state={m['state']}"
                  f"{extra} load={m['load']:.1f}")
        je.close()
        return

    if args.mode == "colocated":
        te = build_te(bundle, params, "colocated", "te-0", tp=args.tp,
                      horizon=args.horizon, fused=not args.no_fused_decode)
        t0 = time.monotonic()
        for p in prompts:
            te.add_request(Request(prompt_tokens=tok.encode(p), sampling=sp))
        comps = te.run_to_completion()
        dt = time.monotonic() - t0
        print(f"served {len(comps)} requests in {dt:.2f}s "
              f"({sum(len(c.tokens) for c in comps)/dt:.1f} tok/s)")
        for c in comps[:3]:
            print(f"  {c.req_id}: ttft={c.ttft*1e3:.0f}ms tpot={c.tpot*1e3:.1f}ms "
                  f"text={tok.decode(c.tokens)[:40]!r}")
        print("prefix-cache:", te.prefix_cache_stats())
        return

    if args.mode == "pd":
        pe = build_te(bundle, params, "prefill", "te-p0", tp=args.tp)
        de = build_te(bundle, params, "decode", "te-d0", tp=args.tp,
                      horizon=args.horizon, fused=not args.no_fused_decode)
        pe.distflow.link_cluster([de.distflow])
        for p in prompts:
            pe.add_request(Request(prompt_tokens=tok.encode(p), sampling=sp))
        comps = []
        for _ in range(10000):
            if not (pe.has_work() or de.has_work()):
                break
            pe.step()
            for rid in pe.pop_migratable():
                # DistFlow v2: sharded device-resident page runs, resharded
                # in flight when P/D tp differ; import overlaps with decode
                pe.migrate_out(rid, de)
            comps.extend(de.step())
        print(f"PD-disaggregated: {len(comps)} completions; "
              f"KV moved {pe.distflow.bytes_moved()/1e6:.2f} MB")
        return

    # scheduled: JE + Algorithm 1 over 2 colocated + 1 PD pair
    cfg_full = get_config(args.arch)
    hs = HeatmapStudy(cfg_full)
    xs, ys, _ = synth_trace(2000, PredictorConfig())
    pparams, acc = train_predictor(PredictorConfig(), xs, ys)
    pred = DecodeLengthPredictor(PredictorConfig(), pparams)
    tes = [TEHandle("te-c0", "colocated", engine=build_te(bundle, params, "colocated", "te-c0", tp=args.tp)),
           TEHandle("te-c1", "colocated", engine=build_te(bundle, params, "colocated", "te-c1", tp=args.tp)),
           TEHandle("te-pd0", "pd_pair")]
    ds = DistributedScheduler(tes, hs.combined(), hs.prefill_lens,
                              hs.decode_ratios, predictor=pred)
    for p in prompts:
        toks = tok.encode(p)
        te = ds.dist_sched(SchedRequest(tokens=toks))
        ds.commit(SchedRequest(tokens=toks), te)
        if te.engine is not None:
            te.engine.add_request(Request(prompt_tokens=toks, sampling=sp))
    done = 0
    for te in tes:
        if te.engine is not None:
            done += len(te.engine.run_to_completion())
    print(f"scheduled mode: {done} completions; decisions={ds.decisions} "
          f"(predictor acc={acc:.3f})")


if __name__ == "__main__":
    main()
