import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) cell:
    jit(step, in_shardings, out_shardings).lower(*ShapeDtypeStructs).compile()
then record memory_analysis (fits-per-device proof), cost_analysis
(FLOPs/bytes), and the collective schedule parsed from the compiled HLO.
Exact roofline terms come from the unrolled per-block probes (see
launch/probes.py and the scan-cost note in DESIGN.md).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                      # full matrix
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
        --shape train_4k --mesh single --probes
Results append to artifacts/dryrun.jsonl (one JSON per cell).
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, get_config, list_configs, shape_applicable
from repro.launch import steps as ST
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.launch.probes import build_probes
from repro.launch.roofline import (CellCost, collective_bytes,
                                   cost_from_compiled, make_terms,
                                   model_flops_for)
from repro.launch.sharding import (batch_spec, cache_specs, named,
                                   param_specs)
from repro.models import serving as S
from repro.models import transformer as T
from repro.training.optimizer import init_opt_state
from jax.sharding import NamedSharding, PartitionSpec as P

DTYPE = jnp.bfloat16


def set_dtype(name: str) -> None:
    global DTYPE
    DTYPE = {"bf16": jnp.bfloat16, "f32": jnp.float32}[name]


def _mem_stats(compiled) -> Dict[str, float]:
    m = compiled.memory_analysis()
    return {"argument_bytes": m.argument_size_in_bytes,
            "output_bytes": m.output_size_in_bytes,
            "temp_bytes": m.temp_size_in_bytes,
            "alias_bytes": m.alias_size_in_bytes,
            "peak_device_bytes": (m.argument_size_in_bytes
                                  + m.output_size_in_bytes
                                  + m.temp_size_in_bytes
                                  - m.alias_size_in_bytes)}


def compile_cell(arch: str, shape_name: str, multi_pod: bool,
                 run_probes: bool = False, opt_flags: Dict[str, Any] = None
                 ) -> Dict[str, Any]:
    """opt_flags (hillclimb knobs, EXPERIMENTS.md §Perf):
      microbatches: int — grad-accumulation override
      perf: dict     — repro.models.perf_flags fields
      fsdp: bool     — False = ZeRO-2-style (params/opt TP-only, replicated
                       over data; right call for small models like rwkv6)
      cp_attention   — context-parallel q rows for non-16-divisible heads
    """
    opt_flags = opt_flags or {}
    from repro.models import perf_flags as PF
    if opt_flags.get("perf"):
        PF.set_flags(**opt_flags["perf"])
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": "multi" if multi_pod else "single",
                           "n_chips": 512 if multi_pod else 256,
                           "dtype": "f32" if DTYPE == jnp.float32 else "bf16"}
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec

    from repro.launch.sharding import block_param_specs
    from repro.models import actsharding as AS
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = dp_axes(mesh)
    mode = "train" if shape.kind == "train" else "serve"
    if not opt_flags.get("fsdp", True):
        mode = "serve"  # ZeRO-2-style: weights TP-only, no data-axis gather
    rec["opt_flags"] = {k: v for k, v in opt_flags.items() if k != "perf"}
    if opt_flags.get("perf"):
        rec["opt_flags"]["perf"] = dict(opt_flags["perf"])

    params_like = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0), DTYPE))
    pspecs = param_specs(cfg, params_like, mode, dp)
    batch = ST.example_batch(cfg, shape, DTYPE)
    t0 = time.monotonic()

    tags = {}
    if cfg.moe is not None:
        # MoE dispatch tensors: the group reshape merges the data-sharded
        # batch and model-sharded seq dims, dropping the model sharding —
        # re-pin tokens to DP and the expert hidden dim to the model axis.
        tags.update({
            "moe_tokens": NamedSharding(mesh, P(dp, None, None)),
            "moe_hidden": NamedSharding(mesh, P(dp, None, None, "model")),
            "moe_out": NamedSharding(mesh, P(dp, None, None, None)),
        })
    if cfg.vision is not None or cfg.encoder is not None:
        # cross-attention q/o: batch over DP, heads over model (the SP-
        # sharded residual stream otherwise leaks replicated score tensors)
        tags["cross_q"] = NamedSharding(mesh, P(dp, None, "model", None))
    if opt_flags.get("cp_attention"):
        tags["attn_q_seq"] = NamedSharding(mesh, P(dp, "model", None, None))
    if opt_flags.get("moe_cshard"):
        # serve-only: shard the dispatch capacity dim over model
        tags["moe_hidden"] = NamedSharding(mesh, P(dp, None, "model", None))
        tags["moe_out"] = NamedSharding(mesh, P(dp, None, "model", None))
    if tags:
        AS.set_tag_specs(tags)
    # all modes: pin per-layer weight slices + LICM barrier (see actsharding)
    AS.set_block_specs(named(mesh, block_param_specs(cfg, params_like,
                                                     mode, dp)))
    if shape.kind == "train":
        # sequence-parallel layer-boundary activations: saved remat
        # residuals shrink 16x and XLA pairs gather/reduce-scatter per layer
        if opt_flags.get("act") == "batch_all":
            # recurrent towers: SP (seq-over-model) forces per-layer gathers;
            # shard batch over every axis instead (pure 256-way DP acts)
            AS.set_act_spec(NamedSharding(
                mesh, P(tuple(dp) + ("model",), None, None)))
        else:
            AS.set_act_spec(NamedSharding(mesh, P(dp, "model", None)))
        opt_like = jax.eval_shape(lambda: init_opt_state(params_like))
        ospecs = {"m": pspecs, "v": pspecs, "step": P()}
        mb = (opt_flags or {}).get("microbatches",
                                   ST.default_microbatches(cfg))
        rec["microbatches"] = mb
        step = ST.build_train_step(cfg, microbatches=mb)
        extra_keys = [k for k in batch if k not in ("tokens", "targets", "mask")]
        extra_like = {k: batch[k] for k in extra_keys}
        extra_specs = {k: batch_spec(shape, dp, 3) for k in extra_keys}

        def fn(params, opt, tokens, targets, mask, extra):
            return step(params, opt, tokens, targets, mask, extra)

        in_sh = named(mesh, (pspecs, ospecs, batch_spec(shape, dp),
                             batch_spec(shape, dp), batch_spec(shape, dp),
                             extra_specs))
        out_sh = named(mesh, (pspecs, ospecs,
                              {"grad_norm": P(), "lr": P(), "loss": P()}))
        jf = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(0, 1))
        lowered = jf.lower(params_like, opt_like, batch["tokens"],
                           batch["targets"], batch["mask"], extra_like)
    elif shape.kind == "prefill":
        step = ST.build_prefill_step(cfg)
        extra_keys = [k for k in batch if k != "tokens"]
        extra_like = {k: batch[k] for k in extra_keys}
        extra_specs = {k: batch_spec(shape, dp, 3) for k in extra_keys}
        cache_like = jax.eval_shape(
            lambda: S.init_cache(cfg, shape.global_batch, shape.seq_len, DTYPE))
        cspecs = cache_specs(cfg, cache_like, shape, dp)
        logits_spec = P(dp if shape.global_batch >= 16 else None, "model")
        in_sh = named(mesh, (pspecs, batch_spec(shape, dp), extra_specs))
        # prefill emits the cache minus `length` bookkeeping differences:
        out_cache_spec = {k: v for k, v in cspecs.items()}
        out_sh = named(mesh, (logits_spec, out_cache_spec))
        jf = jax.jit(lambda p, t, e: step(p, t, e), in_shardings=in_sh,
                     out_shardings=out_sh)
        lowered = jf.lower(params_like, batch["tokens"], extra_like)
    else:  # decode
        step = ST.build_decode_step(cfg)
        use_ring = (PF.get().ring_buffer_decode
                    and cfg.attn_kind in ("swa", "hybrid_rglru"))
        cache_like = jax.eval_shape(
            lambda: S.init_cache(cfg, shape.global_batch, shape.seq_len,
                                 DTYPE, ring=use_ring))
        cspecs = cache_specs(cfg, cache_like, shape, dp)
        tok_spec = P(dp) if shape.global_batch >= 16 else P()
        logits_spec = P(dp if shape.global_batch >= 16 else None, "model")
        in_sh = named(mesh, (pspecs, tok_spec, cspecs))
        out_sh = named(mesh, (logits_spec, cspecs))
        jf = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(2,))
        lowered = jf.lower(params_like, batch["token"], cache_like)

    compiled = lowered.compile()
    rec["compile_s"] = round(time.monotonic() - t0, 1)
    rec["status"] = "ok"
    rec.update(_mem_stats(compiled))
    full_cost = cost_from_compiled(compiled)
    rec["full_artifact"] = {"flops_per_chip": full_cost.flops,
                            "bytes_per_chip": full_cost.bytes_hbm,
                            "collectives": full_cost.coll}

    if run_probes:
        total = CellCost()
        probe_recs = []
        for name, fn, inputs, in_specs, mult in build_probes(
                cfg, shape, params_like, dp, DTYPE, mode=mode,
                act_mode=opt_flags.get("act")):
            tp = time.monotonic()
            pjf = jax.jit(fn, in_shardings=named(mesh, in_specs))
            pcomp = pjf.lower(*inputs).compile()
            c = cost_from_compiled(pcomp)
            total.add(c, mult)
            probe_recs.append({"name": name, "mult": mult,
                               "flops_per_chip": c.flops,
                               "bytes_per_chip": c.bytes_hbm,
                               "collectives": c.coll,
                               "compile_s": round(time.monotonic() - tp, 1)})
        rec["probes"] = probe_recs
        n_chips = rec["n_chips"]
        # f32 probe compiles avoid the CPU backend's bf16-dot emulation
        # copies; halving bytes/wire then models the native-bf16 TPU program
        # (fp32 softmax/optimizer state slightly underestimated — noted in
        # EXPERIMENTS.md). FLOPs are dtype-independent.
        scale = 0.5 if DTYPE == jnp.float32 else 1.0
        total.bytes_hbm *= scale
        total.coll = {k: v * scale for k, v in total.coll.items()}
        rec["bytes_scale"] = scale
        terms = make_terms(total, n_chips, model_flops_for(cfg, shape),
                           multi_pod)
        rec["roofline"] = {
            "compute_s": terms.compute_s, "memory_s": terms.memory_s,
            "collective_s": terms.collective_s, "dominant": terms.dominant,
            "model_flops": terms.model_flops,
            "hlo_flops_global": terms.hlo_flops_global,
            "useful_ratio": terms.useful_flops_ratio,
            "wire_bytes_per_chip": total.wire_bytes(),
        }
    # cleanup AFTER probes — probes must see the same tags/flags the full
    # artifact compiled with
    AS.set_act_spec(None)
    AS.set_block_specs(None)
    AS.set_tag_specs(None)
    PF.reset()
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--probes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun.jsonl")
    ap.add_argument("--dtype", default="bf16", choices=["bf16", "f32"],
                    help="f32 avoids the CPU backend's bf16-dot emulation "
                         "copies; peak/2 then estimates the TPU-native "
                         "bf16 footprint (see EXPERIMENTS.md)")
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cells already recorded in --out")
    args = ap.parse_args()
    set_dtype(args.dtype)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if args.skip_done and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") in ("ok", "skipped"):
                        done.add((r["arch"], r["shape"], r["mesh"],
                                  bool(r.get("probes"))))
                except json.JSONDecodeError:
                    pass

    archs = [args.arch] if args.arch else list_configs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                key = (arch, shape, "multi" if multi else "single", args.probes)
                if key in done:
                    print(f"[dryrun] skip (done) {key}")
                    continue
                print(f"[dryrun] {arch} × {shape} × "
                      f"{'multi' if multi else 'single'} ...", flush=True)
                try:
                    rec = compile_cell(arch, shape, multi, run_probes=args.probes)
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if multi else "single",
                           "status": "error", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
                status = rec.get("status")
                extra = (f" compile={rec.get('compile_s')}s "
                         f"peak={rec.get('peak_device_bytes', 0)/1e9:.2f}GB/chip"
                         if status == "ok" else rec.get("reason", rec.get("error", "")))
                print(f"[dryrun]   -> {status} {extra}", flush=True)


if __name__ == "__main__":
    main()
