"""Sharding rules: ModelConfig + step kind → PartitionSpec pytrees.

Policy (DESIGN.md §5):
  * `model` axis = tensor parallelism. Attention projections shard the flat
    head dim (always 16-divisible across the zoo) when n_heads % 16 == 0;
    archs with awkward head counts (granite 24H, recurrentgemma 10H)
    replicate attention and shard only FFN / vocab / recurrence width.
  * `data` (+ `pod`) axes = batch DP; in train mode weights/opt-state are
    additionally FSDP-sharded over `data` on the d_model dim (ZeRO-style);
    XLA inserts the all-gathers.
  * decode caches shard batch over DP and KV sequence over `model`
    (flash-decode via GSPMD psum); at batch=1 (long_500k) the sequence
    shards over (data×model) — context parallelism.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

TP = 16  # model-axis size of the production mesh


def _div(n: int, k: int = TP) -> bool:
    return n % k == 0


def param_specs(cfg: ModelConfig, params_like, mode: str, dp: Tuple[str, ...]
                ) -> Any:
    """Pytree of PartitionSpec matching `params_like` (train adds FSDP on
    d_model over `data`). `dp` = the mesh's DP axes (("data",) or
    ("pod","data")); FSDP always uses the intra-pod "data" axis."""
    heads_ok = cfg.tp_heads_ok(TP)
    fsdp = "data" if mode == "train" else None

    def spec_for(path: str, leaf) -> P:
        nd = leaf.ndim
        dims: list = [None] * nd

        def last(ax):       # shard last dim
            dims[-1] = ax

        def second_last(ax):
            dims[-2] = ax

        name = path
        if "embed" in name:
            if _div(cfg.padded_vocab):
                dims[0] = "model"
                if fsdp and _div(cfg.d_model, TP):
                    dims[1] = fsdp
            else:
                dims[1] = "model"
            return P(*dims)
        if "lm_head" in name:
            dims[-1] = "model"
            if fsdp:
                dims[-2] = fsdp
            return P(*dims)
        if any(k in name for k in ("['wq']", "['wk']", "['wv']")):
            if heads_ok:
                last("model")
            if fsdp:
                second_last(fsdp)
            return P(*dims)
        if "['wo']" in name:
            if heads_ok:
                second_last("model")
            if fsdp:
                last(fsdp)
            return P(*dims)
        if any(k in name for k in ("['w_gate']", "['w_up']", "['cm_k']")):
            last("model")
            if fsdp:
                second_last(fsdp)
            return P(*dims)
        if any(k in name for k in ("['w_down']", "['cm_v']")):
            second_last("model")
            if fsdp:
                last(fsdp)
            return P(*dims)
        if any(k in name for k in ("['wr']", "['wg']", "['cm_r']")):
            last("model")          # rwkv projections (head-aligned, 32H)
            if fsdp:
                second_last(fsdp)
            return P(*dims)
        if any(k in name for k in ("['w_in']", "['w_gate_in']")):
            last("model")          # rglru width
            if fsdp:
                second_last(fsdp)
            return P(*dims)
        if "['w_out']" in name and "rec" in name:
            second_last("model")
            if fsdp:
                last(fsdp)
            return P(*dims)
        if any(k in name for k in ("['wa']", "['wx']")):
            last("model")
            return P(*dims)
        if any(k in name for k in ("['conv_w']", "['conv_b']", "['lambda_p']")):
            last("model")
            return P(*dims)
        # norms, routers, loras, gates, bonus — replicated
        return P(*dims)

    flat = jax.tree_util.tree_flatten_with_path(params_like)[0]
    specs = [spec_for(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]
    return jax.tree.unflatten(jax.tree.structure(params_like), specs)


def cache_specs(cfg: ModelConfig, cache_like, shape: ShapeConfig,
                dp: Tuple[str, ...]) -> Any:
    """Decode/prefill cache sharding. k/v: (L, B, S, Hkv, hd)."""
    batch_shardable = shape.global_batch >= 16

    def spec_for(path: str, leaf) -> P:
        name = path
        if "length" in name:
            return P(dp) if batch_shardable else P()
        if "['k']" in name or "['v']" in name:
            if batch_shardable:
                return P(None, dp, "model", None, None)
            return P(None, None, ("data", "model"), None, None)
        if "cross_k" in name or "cross_v" in name:
            return P(None, dp if batch_shardable else None, None, None, None)
        if "['state']" in name:       # rwkv (L,B,H,hdk,hdv)
            return P(None, dp if batch_shardable else None,
                     "model" if cfg.tp_heads_ok(TP) else None, None, None)
        if "last_tm" in name or "last_cm" in name:
            return P(None, dp if batch_shardable else None, None)
        if "['h']" in name:           # rglru (L,B,W)
            return P(None, dp if batch_shardable else None, "model")
        if "['conv']" in name:        # (L,B,cw-1,W)
            return P(None, dp if batch_shardable else None, None, "model")
        return P()

    flat = jax.tree_util.tree_flatten_with_path(cache_like)[0]
    specs = [spec_for(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]
    return jax.tree.unflatten(jax.tree.structure(cache_like), specs)


def batch_spec(shape: ShapeConfig, dp: Tuple[str, ...], ndim: int = 2) -> P:
    """Token batches: batch over DP axes."""
    if shape.global_batch < 16:
        return P(*([None] * ndim))
    return P(dp, *([None] * (ndim - 1)))


def block_param_specs(cfg: ModelConfig, params_like, mode: str,
                      dp: Tuple[str, ...]) -> Dict[str, Any]:
    """Per-layer weight specs with the leading (stacked-layer) dim
    stripped — used by actsharding.set_block_specs to pin scan-body weight
    slices to their FSDP storage sharding (gather-inside-loop)."""
    full = param_specs(cfg, params_like, mode, dp)
    out: Dict[str, Any] = {}
    for tower in ("blocks", "enc_blocks", "cross_blocks"):
        if tower in full:
            out[tower] = jax.tree.map(lambda s: P(*s[1:]), full[tower],
                                      is_leaf=lambda x: isinstance(x, P))
    return out


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
