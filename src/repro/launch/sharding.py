"""Sharding rules: ModelConfig + step kind → PartitionSpec pytrees.

Policy (DESIGN.md §5):
  * `model` axis = tensor parallelism. Attention projections shard the flat
    head dim (always 16-divisible across the zoo) when n_heads % 16 == 0;
    archs with awkward head counts (granite 24H, recurrentgemma 10H)
    replicate attention and shard only FFN / vocab / recurrence width.
  * `data` (+ `pod`) axes = batch DP; in train mode weights/opt-state are
    additionally FSDP-sharded over `data` on the d_model dim (ZeRO-style);
    XLA inserts the all-gathers.
  * decode caches shard batch over DP and KV sequence over `model`
    (flash-decode via GSPMD psum); at batch=1 (long_500k) the sequence
    shards over (data×model) — context parallelism.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

TP = 16  # model-axis size of the production mesh


def _div(n: int, k: int = TP) -> bool:
    return n % k == 0


def attn_shardable(cfg: ModelConfig, tp: int) -> bool:
    """Engine-TP predicate: shard attention only when Q *and* KV heads both
    split evenly over the model axis. The launch path only needs the flat
    head dim divisible (matmul sharding), but the serving engine also shards
    the paged KV pool by whole KV heads, so e.g. qwen3 (8 KV heads) at tp=16
    or granite (2 KV heads smoke) at tp=4 must replicate attention and shard
    only FFN / vocab (DESIGN.md §5)."""
    return cfg.n_heads % tp == 0 and cfg.n_kv_heads % tp == 0


def param_specs(cfg: ModelConfig, params_like, mode: str, dp: Tuple[str, ...],
                tp: int = TP, heads_ok: Optional[bool] = None) -> Any:
    """Pytree of PartitionSpec matching `params_like` (train adds FSDP on
    d_model over `data`). `dp` = the mesh's DP axes (("data",) or
    ("pod","data")); FSDP always uses the intra-pod "data" axis. `tp` is the
    model-axis size (16 on the production mesh; the serving engine passes
    EngineConfig.tp); `heads_ok` overrides the attention-shardability rule
    (the engine uses the stricter attn_shardable)."""
    if heads_ok is None:
        heads_ok = cfg.tp_heads_ok(tp)
    fsdp = "data" if mode == "train" else None

    def spec_for(path: str, leaf) -> P:
        nd = leaf.ndim
        dims: list = [None] * nd

        def last(ax):       # shard last dim
            dims[-1] = ax

        def second_last(ax):
            dims[-2] = ax

        name = path
        if "embed" in name:
            if _div(cfg.padded_vocab, tp):
                dims[0] = "model"
                if fsdp and _div(cfg.d_model, tp):
                    dims[1] = fsdp
            else:
                dims[1] = "model"
            return P(*dims)
        if "lm_head" in name:
            dims[-1] = "model"
            if fsdp:
                dims[-2] = fsdp
            return P(*dims)
        if any(k in name for k in ("['wq']", "['wk']", "['wv']")):
            if heads_ok:
                last("model")
            if fsdp:
                second_last(fsdp)
            return P(*dims)
        if "['wo']" in name:
            if heads_ok:
                second_last("model")
            if fsdp:
                last(fsdp)
            return P(*dims)
        if any(k in name for k in ("['w_gate']", "['w_up']", "['cm_k']")):
            last("model")
            if fsdp:
                second_last(fsdp)
            return P(*dims)
        if any(k in name for k in ("['w_down']", "['cm_v']")):
            second_last("model")
            if fsdp:
                last(fsdp)
            return P(*dims)
        if any(k in name for k in ("['wr']", "['wg']", "['cm_r']")):
            last("model")          # rwkv projections (head-aligned, 32H)
            if fsdp:
                second_last(fsdp)
            return P(*dims)
        if any(k in name for k in ("['w_in']", "['w_gate_in']")):
            last("model")          # rglru width
            if fsdp:
                second_last(fsdp)
            return P(*dims)
        if "['w_out']" in name and "rec" in name:
            second_last("model")
            if fsdp:
                last(fsdp)
            return P(*dims)
        if any(k in name for k in ("['wa']", "['wx']")):
            last("model")
            return P(*dims)
        if any(k in name for k in ("['conv_w']", "['conv_b']", "['lambda_p']")):
            last("model")
            return P(*dims)
        # norms, routers, loras, gates, bonus — replicated
        return P(*dims)

    flat = jax.tree_util.tree_flatten_with_path(params_like)[0]
    specs = [spec_for(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]
    return jax.tree.unflatten(jax.tree.structure(params_like), specs)


def cache_specs(cfg: ModelConfig, cache_like, shape: ShapeConfig,
                dp: Tuple[str, ...], tp: int = TP) -> Any:
    """Decode/prefill cache sharding. k/v: (L, B, S, Hkv, hd)."""
    batch_shardable = shape.global_batch >= 16

    def spec_for(path: str, leaf) -> P:
        name = path
        if "length" in name:
            return P(dp) if batch_shardable else P()
        if "['k']" in name or "['v']" in name:
            if batch_shardable:
                return P(None, dp, "model", None, None)
            return P(None, None, ("data", "model"), None, None)
        if "cross_k" in name or "cross_v" in name:
            return P(None, dp if batch_shardable else None, None, None, None)
        if "['state']" in name:       # rwkv (L,B,H,hdk,hdv)
            return P(None, dp if batch_shardable else None,
                     "model" if cfg.tp_heads_ok(tp) else None, None, None)
        if "last_tm" in name or "last_cm" in name:
            return P(None, dp if batch_shardable else None, None)
        if "['h']" in name:           # rglru (L,B,W)
            return P(None, dp if batch_shardable else None, "model")
        if "['conv']" in name:        # (L,B,cw-1,W)
            return P(None, dp if batch_shardable else None, None, "model")
        return P()

    flat = jax.tree_util.tree_flatten_with_path(cache_like)[0]
    specs = [spec_for(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]
    return jax.tree.unflatten(jax.tree.structure(cache_like), specs)


def batch_spec(shape: ShapeConfig, dp: Tuple[str, ...], ndim: int = 2) -> P:
    """Token batches: batch over DP axes."""
    if shape.global_batch < 16:
        return P(*([None] * ndim))
    return P(dp, *([None] * (ndim - 1)))


def block_param_specs(cfg: ModelConfig, params_like, mode: str,
                      dp: Tuple[str, ...]) -> Dict[str, Any]:
    """Per-layer weight specs with the leading (stacked-layer) dim
    stripped — used by actsharding.set_block_specs to pin scan-body weight
    slices to their FSDP storage sharding (gather-inside-loop)."""
    full = param_specs(cfg, params_like, mode, dp)
    out: Dict[str, Any] = {}
    for tower in ("blocks", "enc_blocks", "cross_blocks"):
        if tower in full:
            out[tower] = jax.tree.map(lambda s: P(*s[1:]), full[tower],
                                      is_leaf=lambda x: isinstance(x, P))
    return out


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Engine (FLOWSERVE TE) shardings: a TE's NPUs form a 1×tp ("data","model")
# mesh; DP happens across TEs, not inside one, so only the model axis is
# populated here.
# ---------------------------------------------------------------------------


def prune_unsplittable(spec_tree, arrays_like, mesh) -> Any:
    """Replace mesh-axis entries that do not divide their dim evenly with
    replication. GSPMD would pad uneven shards; the serving hot path prefers
    plain replication for the handful of odd dims in the zoo (granite 24H,
    recurrentgemma 10H, awkward vocab remainders)."""
    def prune(spec, leaf):
        dims = list(spec) + [None] * (leaf.ndim - len(spec))
        for i, ax in enumerate(dims):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            if size == 0 or leaf.shape[i] % size != 0:
                dims[i] = None
        return P(*dims)

    return jax.tree.map(prune, spec_tree, arrays_like,
                        is_leaf=lambda x: isinstance(x, P))


def engine_param_shardings(cfg: ModelConfig, params_like, mesh) -> Any:
    """NamedSharding pytree for a TE's weights on its 1×tp mesh."""
    tp = int(mesh.shape["model"])
    specs = param_specs(cfg, params_like, "serve", ("data",), tp=tp,
                        heads_ok=attn_shardable(cfg, tp))
    return named(mesh, prune_unsplittable(specs, params_like, mesh))


def engine_kv_pool_sharding(cfg: ModelConfig, mesh) -> NamedSharding:
    """Paged KV pool (L, n_pages, page_size, Hkv, hd): whole KV heads shard
    over `model` when attention is TP-sharded, else the pool replicates."""
    tp = int(mesh.shape["model"])
    spec = P(None, None, None, "model", None) if attn_shardable(cfg, tp) else P()
    return NamedSharding(mesh, spec)


def engine_kv_run_sharding(cfg: ModelConfig, mesh) -> NamedSharding:
    """Placement of a migrated page-run payload (L, NP_run, P, Hkv, hd) on a
    destination TE's mesh — DistFlow v2's resharding rule (DESIGN.md §7).
    Runs have the pool's rank, so the pool spec applies verbatim: when the
    source and destination tp differ, ``jax.device_put`` onto this sharding
    re-splits the KV heads in flight (e.g. P at tp=4 → D at tp=2 merges
    adjacent head shards pairwise)."""
    return engine_kv_pool_sharding(cfg, mesh)


def engine_decode_state_sharding(mesh) -> NamedSharding:
    """Placement of the decode hot loop's persistent carried state — block
    table, lengths, last-token, active-mask and sampling-param vectors plus
    the PRNG key (DESIGN.md §8). These are O(batch) scalars consumed by
    every shard of the SPMD decode step, so they replicate over the TE's
    whole 1×tp mesh; the fused decode jit pins them in AND out so the
    carried state never migrates off-policy between horizons."""
    return NamedSharding(mesh, P())


def engine_cache_shardings(cfg: ModelConfig, cache_like, mesh,
                           n_slots: int, max_len: int) -> Any:
    """SlotRunner dense caches: reuse cache_specs with an engine-shaped
    ShapeConfig (slot batches are small, so k/v shard the sequence dim over
    (data×model) — context parallelism inside the TE)."""
    tp = int(mesh.shape["model"])
    shape = ShapeConfig("engine_slots", "decode", max_len, n_slots)
    specs = cache_specs(cfg, cache_like, shape, ("data",), tp=tp)
    return named(mesh, prune_unsplittable(specs, cache_like, mesh))
