"""Roofline accounting from compiled XLA artifacts.

Three terms per (arch × shape × mesh) cell, all in seconds:
    compute    = FLOPs_per_chip / peak_FLOPs
    memory     = bytes_per_chip / HBM_bw
    collective = collective_bytes_per_chip / (links × link_bw)

Sources: ``compiled.cost_analysis()`` (per-device FLOPs / bytes accessed of
the partitioned module) and the compiled HLO text for collective operand
bytes. Scanned artifacts undercount loop bodies, so cells are priced from
the unrolled per-block probes × layer multipliers (launch/probes.py).

Hardware constants (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI with 2 usable links per axis-neighbor torus direction.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW_PER_LINK = 50e9
ICI_LINKS = 2                  # effective concurrent links per collective
DCN_BW = 25e9                  # per-host inter-pod bandwidth (pod axis)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Sum byte sizes of every array shape in an HLO type string (handles
    tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes summed over the module. Fusion
    bodies are included; while bodies appear once (probe-scaling applies).
    Result bytes are the standard proxy for wire bytes (all-gather output,
    all-reduce ring ≈ 2× — we report raw and let the term apply factors)."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if " = " not in ls:
            continue
        _, rhs = ls.split(" = ", 1)
        for kind in _COLLECTIVES:
            # match "bf16[...] all-reduce(" or "(f32[..],..) all-to-all("
            if f" {kind}(" in rhs or rhs.startswith(f"{kind}("):
                type_part = rhs.split(f" {kind}(")[0] if f" {kind}(" in rhs else ""
                out[kind] += _shape_bytes(type_part)
                break
        # also catch *-start forms (async collectives)
        for kind in _COLLECTIVES:
            if f" {kind}-start(" in rhs:
                type_part = rhs.split(f" {kind}-start(")[0]
                out[kind] += _shape_bytes(type_part)
                break
    return out


# wire-traffic multipliers per collective kind (ring algorithms, n large)
_WIRE_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


@dataclass
class CellCost:
    flops: float = 0.0                # per-chip
    bytes_hbm: float = 0.0            # per-chip "bytes accessed"
    coll: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "CellCost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes_hbm += other.bytes_hbm * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult

    def wire_bytes(self) -> float:
        return sum(v * _WIRE_FACTOR.get(k, 1.0) for k, v in self.coll.items())


def cost_from_compiled(compiled) -> CellCost:
    ca = compiled.cost_analysis()
    txt = compiled.as_text()
    return CellCost(flops=float(ca.get("flops", 0.0)),
                    bytes_hbm=float(ca.get("bytes accessed", 0.0)),
                    coll={k: float(v) for k, v in collective_bytes(txt).items()})


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float                # 6·N·D (global, analytic)
    hlo_flops_global: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        # optimistic overlap model: the dominant term is the floor
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops_global if self.hlo_flops_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the pure-compute roofline achieved by the modeled
        step time: t_compute_ideal(MODEL_FLOPS) / t_step."""
        ideal = self.model_flops and self.model_flops  # placeholder, set below
        return 0.0


def make_terms(cost: CellCost, n_chips: int, model_flops_global: float,
               multi_pod: bool = False) -> RooflineTerms:
    compute_s = cost.flops / PEAK_FLOPS
    memory_s = cost.bytes_hbm / HBM_BW
    # pod-axis traffic rides DCN; intra-pod rides ICI. Without per-axis
    # attribution from HLO we price all wire bytes at ICI (single-pod) and
    # report the multi-pod delta separately in EXPERIMENTS.md.
    coll_s = cost.wire_bytes() / (ICI_LINKS * ICI_BW_PER_LINK)
    return RooflineTerms(compute_s=compute_s, memory_s=memory_s,
                         collective_s=coll_s,
                         model_flops=model_flops_global,
                         hlo_flops_global=cost.flops * n_chips)


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D for train, 2·N·D for inference (per step)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch                     # one token per sequence
    return 2.0 * n_active * tokens
