"""Training launcher (fine-tune jobs — the TRAINING job kind).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b \
        --steps 100 --ckpt-dir /tmp/ckpt --resume
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.data import DataConfig, PackedDataset
from repro.models import get_model
from repro.training import (CheckpointManager, OptimizerConfig, TrainConfig,
                            train)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="use the full config (requires real accelerators)")
    args = ap.parse_args()

    bundle = get_model(args.arch, smoke=not args.full)
    params = bundle.init_params(jax.random.PRNGKey(0),
                                jnp.bfloat16 if args.full else jnp.float32)
    ds = PackedDataset(DataConfig(seq_len=args.seq_len, batch_size=args.batch,
                                  n_docs=2048))
    tcfg = TrainConfig(
        steps=args.steps, log_every=10, ckpt_every=args.ckpt_every,
        microbatches=args.microbatches,
        opt=OptimizerConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                            total_steps=args.steps))
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    _, stats = train(bundle, params, ds.batches(epochs=1000), tcfg, ckpt=ckpt,
                     resume=args.resume)
    print(f"done: loss {stats['loss_first']:.3f} -> {stats['loss_last']:.3f} "
          f"in {stats['wall']:.1f}s")


if __name__ == "__main__":
    main()
