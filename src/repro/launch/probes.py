"""Exact-cost probes for the roofline (DESIGN.md; see the scan-cost note).

XLA's HloCostAnalysis counts a while-loop body ONCE, so the scanned full
artifacts undercount FLOPs by the trip count. Probes fix this: we compile
*single-block* functions (attention chunk-scans unrolled ⇒ no while loops
anywhere) under the same mesh/shardings and scale by the block multiplier.

    roofline_cost(cell) = Σ_kind  mult_kind × cost(block_kind) + cost(outer)

Train probes wrap the block in jax.checkpoint and differentiate, matching
the remat schedule of the real train step; they include the AdamW update
of the block's params.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.sharding import batch_spec, param_specs
from repro.models import layers as L
from repro.models import serving as S
from repro.models import transformer as T
from repro.models.model_factory import cross_entropy
from repro.training.optimizer import OptimizerConfig, adamw_update, init_opt_state

Probe = Tuple[str, Callable, Tuple, Any, int]   # (name, fn, inputs, in_specs, mult)

_OPT = OptimizerConfig()


def _counts(cfg: ModelConfig) -> Dict[str, int]:
    kinds = cfg.layer_kinds()
    return {
        "attn_global": sum(1 for k in kinds if k == "attn_global"),
        "attn_local": sum(1 for k in kinds if k == "attn_local"),
        "rwkv": sum(1 for k in kinds if k == "rwkv"),
        "rglru": sum(1 for k in kinds if k == "rglru"),
    }


def _block_params_like(cfg: ModelConfig, params_like, kind: str):
    if kind in ("attn_global", "attn_local"):
        if cfg.attn_kind == "hybrid_rglru":
            return params_like["attn_blocks"][0]
        return jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype),
                            params_like["blocks"])
    if kind == "rwkv":
        return jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype),
                            params_like["blocks"])
    if kind == "rglru":
        return params_like["rglru_blocks"][0]
    if kind == "cross":
        return jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype),
                            params_like["cross_blocks"])
    if kind == "enc":
        return jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype),
                            params_like["enc_blocks"])
    raise KeyError(kind)


def _block_spec_tree(cfg: ModelConfig, block_like, mode: str, dp):
    """Param specs for a single (unstacked) block: reuse param_specs by
    wrapping in the stacked-tree naming so path rules match."""
    wrapped = {"blocks": jax.tree.map(
        lambda a: jax.ShapeDtypeStruct((1,) + a.shape, a.dtype), block_like)}
    spec = param_specs(cfg, wrapped, mode, dp)["blocks"]
    return jax.tree.map(lambda s: P(*s[1:]), spec,
                        is_leaf=lambda x: isinstance(x, P))


def _apply_block(cfg: ModelConfig, kind: str, p, x, positions, win, extra=None,
                 decode_cache=None):
    """Single block fwd (probe mode: flash scans unrolled)."""
    if kind in ("attn_global", "attn_local"):
        if decode_cache is not None:
            lengths, k_c, v_c = decode_cache
            o, k_c, v_c = S._decode_attention(cfg, p, x, positions, k_c, v_c,
                                              win, lengths)
            h = x + S._post_attn(cfg, p, o)
            hh = L.apply_norm(h, p["ln2"], cfg.norm)
            if "moe" in p:
                from repro.models import moe as M
                m = M.moe_apply(p["moe"], hh, cfg.moe, cfg.mlp_act, groups=1)
            else:
                m = L.mlp_apply(p["mlp"], hh, cfg.mlp_act)
            if cfg.post_norms:
                m = L.apply_norm(m, p["ln2_post"], cfg.norm)
            return h + m
        x, _ = T.attn_block_apply(cfg, p, x, positions, win, None,
                                  attn_impl="flash", unroll_probe=True)
        return x
    if kind == "rwkv":
        b, s, d = x.shape
        h = cfg.d_model // cfg.rwkv.head_dim
        st = jnp.zeros((b, h, cfg.rwkv.head_dim, cfg.rwkv.head_dim), jnp.float32)
        lx = jnp.zeros((b, d), x.dtype)
        x, _, _, _ = T.rwkv_block_apply(cfg, p, x, st, lx, lx,
                                        chunked=s > 1, unroll_probe=True)
        return x
    if kind == "rglru":
        b = x.shape[0]
        w, cw = cfg.rglru.lru_width, cfg.rglru.conv1d_width
        x, _, _ = T.rglru_block_apply(cfg, p, x,
                                      jnp.zeros((b, w), jnp.float32),
                                      jnp.zeros((b, cw - 1, w), x.dtype),
                                      decode=x.shape[1] == 1)
        return x
    raise KeyError(kind)


def build_probes(cfg: ModelConfig, shape: ShapeConfig, params_like, dp,
                 dtype=jnp.bfloat16, mode: str = None,
                 act_mode: str = None) -> List[Probe]:
    """Probe list for one cell. Multipliers sum over the layer schedule.
    `mode` overrides the param-spec mode (e.g. "serve" for ZeRO-2 train);
    `act_mode` mirrors the launcher's activation layout so probe inputs see
    the same sharding the scanned artifact's layer boundaries use."""
    b, s = shape.global_batch, shape.seq_len
    if mode is None:
        mode = "train" if shape.kind == "train" else "serve"
    counts = _counts(cfg)
    x_sds = jax.ShapeDtypeStruct((b, s if shape.kind != "decode" else 1,
                                  cfg.d_model), dtype)
    x_spec = batch_spec(shape, dp, ndim=3)
    if shape.kind == "train":
        if act_mode == "batch_all":
            x_spec = P(tuple(dp) + ("model",), None, None)
        elif s % 16 == 0:
            x_spec = P(dp, "model", None)   # sequence-parallel boundaries
    probes: List[Probe] = []

    def add_block(kind: str, mult: int, win):
        if mult == 0:
            return
        block_like = _block_params_like(cfg, params_like, kind)
        block_specs = _block_spec_tree(cfg, block_like, mode, dp)
        positions_fn = _positions(shape, b, s)
        if shape.kind == "train":
            def fn(p, x):
                def f(p_, x_):
                    blk = functools.partial(_apply_block, cfg, kind)
                    out = jax.checkpoint(blk)(p_, x_, positions_fn(), win)
                    return jnp.sum(out.astype(jnp.float32))
                loss, grads = jax.value_and_grad(f, argnums=(0, 1))(p, x)
                gp, gx = grads
                p2, _, _ = adamw_update(_OPT, p, gp, init_opt_state(p))
                acc = jnp.sum(gx.astype(jnp.float32))
                for leaf in jax.tree.leaves(p2):
                    if leaf.dtype != jnp.int32:
                        acc = acc + jnp.sum(leaf.astype(jnp.float32))
                return acc
            probes.append((f"block_{kind}", fn, (block_like, x_sds),
                           (block_specs, x_spec), mult))
        elif shape.kind == "decode":
            from repro.models import perf_flags as PF
            from repro.models.serving import ring_len
            s_kv = s
            if (PF.get().ring_buffer_decode
                    and cfg.attn_kind in ("swa", "hybrid_rglru")):
                s_kv = min(s, ring_len(cfg))
            kv_sds = jax.ShapeDtypeStruct((b, s_kv, cfg.n_kv_heads, cfg.head_dim), dtype)
            kv_spec = (P(dp, "model", None, None) if b >= 16
                       else P(None, ("data", "model"), None, None))
            if kind in ("attn_global", "attn_local"):
                def fn(p, x, k_c, v_c):
                    lengths = jnp.full((b,), s - 1, jnp.int32)
                    positions = lengths[:, None]
                    out = _apply_block(cfg, kind, p, x, positions, win,
                                       decode_cache=(lengths, k_c, v_c))
                    return out
                probes.append((f"block_{kind}", fn,
                               (block_like, x_sds, kv_sds, kv_sds),
                               (block_specs, x_spec, kv_spec, kv_spec), mult))
            else:
                def fn(p, x):
                    positions = jnp.full((b, 1), s - 1, jnp.int32)
                    return _apply_block(cfg, kind, p, x, positions, win)
                probes.append((f"block_{kind}", fn, (block_like, x_sds),
                               (block_specs, x_spec), mult))
        else:  # prefill
            def fn(p, x):
                return _apply_block(cfg, kind, p, x, positions_fn(), win)
            probes.append((f"block_{kind}", fn, (block_like, x_sds),
                           (block_specs, x_spec), mult))

    win_local = jnp.int32(cfg.window or T.GLOBAL_WINDOW)
    add_block("attn_global", counts["attn_global"], jnp.int32(T.GLOBAL_WINDOW))
    add_block("attn_local", counts["attn_local"], win_local)
    add_block("rwkv", counts["rwkv"], None)
    add_block("rglru", counts["rglru"], None)

    # cross-attention blocks (vlm / enc-dec decoders)
    if cfg.vision is not None or cfg.encoder is not None:
        probes.append(_cross_probe(cfg, shape, params_like, dp, dtype, x_sds,
                                   x_spec, mode))
    # encoder tower (enc-dec): runs on prefill/train steps only
    if cfg.encoder is not None and shape.kind != "decode":
        probes.append(_encoder_probe(cfg, shape, params_like, dp, dtype, mode))

    probes.append(_outer_probe(cfg, shape, params_like, dp, dtype))
    return probes


def _cross_probe(cfg, shape, params_like, dp, dtype, x_sds, x_spec, mode) -> Probe:
    b = shape.global_batch
    mem_len = cfg.vision.n_patches if cfg.vision is not None else cfg.encoder.n_frames
    mult = len(cfg.cross_attn_layers()) if cfg.vision is not None else cfg.n_layers
    gated = cfg.vision is not None
    block_like = _block_params_like(cfg, params_like, "cross")
    block_specs = _block_spec_tree(cfg, block_like, mode, dp)
    mem_sds = jax.ShapeDtypeStruct((b, mem_len, cfg.d_model), dtype)
    mem_spec = batch_spec(shape, dp, ndim=3)

    def fwd(p, x, mem):
        mk, mv = T.memory_kv(cfg, p["attn"], mem)
        return T.cross_block_apply(cfg, p, x, mk, mv, gated=gated)

    if shape.kind == "train":
        def fn(p, x, mem):
            def f(p_, x_):
                out = jax.checkpoint(fwd)(p_, x_, mem)
                return jnp.sum(out.astype(jnp.float32))
            _, (gp, gx) = jax.value_and_grad(f, argnums=(0, 1))(p, x)
            p2, _, _ = adamw_update(_OPT, p, gp, init_opt_state(p))
            acc = jnp.sum(gx.astype(jnp.float32))
            for leaf in jax.tree.leaves(p2):
                if leaf.dtype != jnp.int32:
                    acc = acc + jnp.sum(leaf.astype(jnp.float32))
            return acc
    else:
        fn = fwd
    return ("block_cross", fn, (block_like, x_sds, mem_sds),
            (block_specs, x_spec, mem_spec), mult)


def _encoder_probe(cfg, shape, params_like, dp, dtype, mode) -> Probe:
    b = shape.global_batch
    f_len = cfg.encoder.n_frames
    block_like = _block_params_like(cfg, params_like, "enc")
    block_specs = _block_spec_tree(cfg, block_like, mode, dp)
    x_sds = jax.ShapeDtypeStruct((b, f_len, cfg.d_model), dtype)
    x_spec = batch_spec(shape, dp, ndim=3)

    def fwd(p, x):
        pos = jnp.broadcast_to(jnp.arange(f_len, dtype=jnp.int32)[None], (b, f_len))
        h = L.apply_norm(x, p["ln1"], cfg.norm)
        q, k, v = L.attn_qkv(p["attn"], h, cfg.n_heads, cfg.n_kv_heads,
                             cfg.head_dim, pos, cfg.rope_theta, cfg.qk_norm)
        o = L.flash_attention(q, k, v, pos, pos, softcap=cfg.attn_logit_softcap,
                              chunk=min(1024, f_len), unroll=True, causal=False)
        x = x + L.attn_out(p["attn"], o)
        h = L.apply_norm(x, p["ln2"], cfg.norm)
        return x + L.mlp_apply(p["mlp"], h, cfg.mlp_act)

    if shape.kind == "train":
        def fn(p, x):
            def f(p_, x_):
                return jnp.sum(jax.checkpoint(fwd)(p_, x_).astype(jnp.float32))
            _, (gp, gx) = jax.value_and_grad(f, argnums=(0, 1))(p, x)
            p2, _, _ = adamw_update(_OPT, p, gp, init_opt_state(p))
            acc = jnp.sum(gx.astype(jnp.float32))
            for leaf in jax.tree.leaves(p2):
                if leaf.dtype != jnp.int32:
                    acc = acc + jnp.sum(leaf.astype(jnp.float32))
            return acc
    else:
        fn = fwd
    return ("block_enc", fn, (block_like, x_sds), (block_specs, x_spec),
            cfg.encoder.n_layers)


def _positions(shape, b, s):
    if shape.kind == "decode":
        return lambda: jnp.full((b, 1), s - 1, jnp.int32)
    return lambda: jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))


def _outer_probe(cfg: ModelConfig, shape: ShapeConfig, params_like, dp,
                 dtype) -> Probe:
    """Embedding + final norm + head (+ loss & grads & adam in train)."""
    b = shape.global_batch
    s = shape.seq_len if shape.kind != "decode" else 1
    mode = "train" if shape.kind == "train" else "serve"
    sub_like = {"embed": params_like["embed"],
                "final_norm": params_like["final_norm"]}
    if "lm_head" in params_like:
        sub_like["lm_head"] = params_like["lm_head"]
    sub_specs = param_specs(cfg, sub_like, mode, dp)
    tok_sds = jax.ShapeDtypeStruct((b, s), jnp.int32)
    tok_spec = batch_spec(shape, dp, ndim=2)

    if shape.kind == "train":
        def fn(p, tokens, targets, mask):
            def f(p_):
                x = T.embed(cfg, p_, tokens)
                logits = T.unembed(cfg, p_, x)
                return cross_entropy(logits, targets, mask, cfg.vocab_size)
            loss, g = jax.value_and_grad(f)(p)
            p2, _, _ = adamw_update(_OPT, p, g, init_opt_state(p))
            return loss, jax.tree.map(lambda a: jnp.sum(a.astype(jnp.float32)), p2)
        mask_sds = jax.ShapeDtypeStruct((b, s), jnp.float32)
        return ("outer", fn, (sub_like, tok_sds, tok_sds, mask_sds),
                (sub_specs, tok_spec, tok_spec, tok_spec), 1)

    def fn(p, tokens):
        x = T.embed(cfg, p, tokens)
        return T.unembed(cfg, p, x[:, -1:])
    return ("outer", fn, (sub_like, tok_sds), (sub_specs, tok_spec), 1)
