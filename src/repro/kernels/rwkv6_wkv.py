"""Pallas TPU chunked WKV6 kernel (RWKV-6 "Finch" recurrence).

TPU adaptation of the (GPU, warp-per-head) WKV kernels: instead of a
per-timestep recurrence we run the chunk-parallel schedule — within-chunk
pairwise interactions become (C×C)·(C×hd) MXU matmuls in log-decay space;
the cross-chunk state (hd×hd per head, fp32) lives in VMEM scratch and is
carried across the innermost grid dimension. Grid: (B, H, NC).
VMEM per step: r/k/v/w chunks (C, hd), state (hd, hd) fp32, out (C, hd).
Validated in interpret mode against the sequential oracle ref.wkv6_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.models.rwkv6 import LOG_DECAY_CLAMP


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_ref,
            *, chunk: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    rb = r_ref[0, :, 0, :].astype(jnp.float32)                   # (C, hd)
    kb = k_ref[0, :, 0, :].astype(jnp.float32)
    vb = v_ref[0, :, 0, :].astype(jnp.float32)
    wb = w_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)                             # (hd,)
    s = state_ref[...]                                           # (hd_k, hd_v)

    lw = jnp.clip(jnp.log(jnp.maximum(wb, 1e-38)), LOG_DECAY_CLAMP, 0.0)
    cum = jnp.cumsum(lw, axis=0)                                 # (C, hd)
    dec_in = jnp.exp(cum - lw)                                   # Π_{j<i} w
    y_state = jax.lax.dot_general(rb * dec_in, s, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    q_side = rb * jnp.exp(cum - lw)
    k_side = kb * jnp.exp(-cum)
    scores = jax.lax.dot_general(q_side, k_side, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (C, C)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(jj < ii, scores, 0.0)
    bonus = jnp.sum(rb * u[None, :] * kb, axis=1, keepdims=True)  # (C, 1)
    y = y_state + jax.lax.dot_general(scores, vb, (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
    y = y + bonus * vb
    o_ref[0, :, 0, :] = y.astype(o_ref.dtype)

    total = cum[-1:, :]                                          # (1, hd)
    k_dec = kb * jnp.exp(total - cum)                            # k_j Π_{l>j} w_l
    state_ref[...] = (jnp.exp(total[0])[:, None] * s
                      + jax.lax.dot_general(k_dec, vb, (((0,), (0,)), ((), ())),
                                            preferred_element_type=jnp.float32))


def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array, u: jax.Array,
         chunk: int = 64, interpret: bool = True) -> jax.Array:
    """r,k,v,w: (B, T, H, hd); u: (H, hd). Returns y: (B, T, H, hd).
    T must be a multiple of `chunk` (pad upstream with w=1, k=0)."""
    b, t, h, hd = r.shape
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk

    kernel = functools.partial(_kernel, chunk=chunk)
    spec = pl.BlockSpec((1, chunk, 1, hd), lambda bi, hi, ci: (bi, ci, hi, 0))
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, hd), lambda bi, hi, ci: (hi, 0))],
        out_specs=spec,
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((b, t, h, hd), r.dtype),
        interpret=interpret,
    )(r, k, v, w, u)
    return out
