"""Public jit'd wrappers for the kernel suite.

``impl`` selects the backend:
  * "pallas"    — the Pallas kernel (interpret mode on CPU; compiled on TPU)
  * "ref"       — the pure-jnp oracle (fast on CPU; GSPMD-partitionable)
  * "auto"      — pallas on TPU, ref elsewhere (the engine default)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.flash_prefill import flash_prefill as _flash_pallas
from repro.kernels.paged_attention import paged_attention as _paged_pallas
from repro.kernels.rglru_scan import rglru as _rglru_pallas
from repro.kernels.rwkv6_wkv import wkv6 as _wkv6_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    return impl


@functools.partial(jax.jit, static_argnames=("softcap", "window", "impl"))
def paged_attention(q, k_pages, v_pages, block_tables, lengths,
                    softcap: Optional[float] = None,
                    window: Optional[int] = None, impl: str = "auto"):
    impl = _resolve(impl)
    if impl == "pallas":
        return _paged_pallas(q, k_pages, v_pages, block_tables, lengths,
                             softcap=softcap, window=window,
                             interpret=not _on_tpu())
    return _ref.paged_attention_ref(q, k_pages, v_pages, block_tables, lengths,
                                    softcap=softcap, window=window)


@functools.partial(jax.jit, static_argnames=("softcap", "window", "block_q",
                                             "block_k", "impl"))
def flash_prefill(q, k, v, softcap: Optional[float] = None,
                  window: Optional[int] = None, block_q: int = 128,
                  block_k: int = 128, impl: str = "auto"):
    impl = _resolve(impl)
    if impl == "pallas":
        return _flash_pallas(q, k, v, softcap=softcap, window=window,
                             block_q=block_q, block_k=block_k,
                             interpret=not _on_tpu())
    return _ref.flash_prefill_ref(q, k, v, softcap=softcap, window=window)


@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def wkv6(r, k, v, w, u, chunk: int = 64, impl: str = "auto"):
    impl = _resolve(impl)
    if impl == "pallas":
        return _wkv6_pallas(r, k, v, w, u, chunk=chunk, interpret=not _on_tpu())
    y, _ = _ref.wkv6_ref(r, k, v, w, u)
    return y


@functools.partial(jax.jit, static_argnames=("chunk", "block_w", "impl"))
def rglru(a, b, h0, chunk: int = 128, block_w: int = 128, impl: str = "auto"):
    impl = _resolve(impl)
    if impl == "pallas":
        return _rglru_pallas(a, b, h0, chunk=chunk, block_w=block_w,
                             interpret=not _on_tpu())
    return _ref.rglru_ref(a, b, h0)
