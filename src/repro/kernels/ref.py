"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each kernel's test sweeps shapes and
dtypes and asserts allclose against the function here. The engine can also
run on these directly (CPU path)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def paged_attention_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                        block_tables: jax.Array, lengths: jax.Array,
                        softcap: Optional[float] = None,
                        window: Optional[int] = None) -> jax.Array:
    """Decode attention over a paged KV cache.

    q: (B, H, hd) — one query per sequence (position = lengths-1).
    k_pages / v_pages: (NP, P, Hkv, hd) global page pools.
    block_tables: (B, MAXP) int32 page ids (padded with 0; masked by length).
    lengths: (B,) int32 — valid tokens per sequence (incl. current token).
    Returns (B, H, hd).
    """
    b, h, hd = q.shape
    np_, p, hkv, _ = k_pages.shape
    maxp = block_tables.shape[1]
    g = h // hkv
    scale = 1.0 / math.sqrt(hd)

    k = k_pages[block_tables].reshape(b, maxp * p, hkv, hd)      # (B, L, Hkv, hd)
    v = v_pages[block_tables].reshape(b, maxp * p, hkv, hd)
    pos = jnp.arange(maxp * p, dtype=jnp.int32)[None, :]
    valid = pos < lengths[:, None]
    if window is not None:
        valid &= pos > (lengths[:, None] - 1 - window)

    qh = q.reshape(b, hkv, g, hd).astype(jnp.float32)
    kh = k.transpose(0, 2, 1, 3).astype(jnp.float32)             # (B, Hkv, L, hd)
    vh = v.transpose(0, 2, 1, 3).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhld->bhgl", qh, kh) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgl,bhld->bhgd", pr, vh)
    return o.reshape(b, h, hd).astype(q.dtype)


def flash_prefill_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                      softcap: Optional[float] = None,
                      window: Optional[int] = None) -> jax.Array:
    """Causal (optionally sliding-window, softcapped) self-attention.
    q: (B, S, H, hd); k, v: (B, S, Hkv, hd). Returns (B, S, H, hd)."""
    b, s, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(hd)
    qp = jnp.arange(s)
    mask = qp[None, :, None] >= qp[None, None, :]
    if window is not None:
        mask &= qp[None, None, :] > (qp[None, :, None] - window)

    qh = q.reshape(b, s, hkv, g, hd).astype(jnp.float32)
    sc = jnp.einsum("bqhgd,bkhd->bhgqk", qh, k.astype(jnp.float32)) * scale
    if softcap is not None:
        sc = softcap * jnp.tanh(sc / softcap)
    sc = jnp.where(mask[:, None, None], sc, -1e30)
    pr = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", pr, v.astype(jnp.float32))
    return o.reshape(b, s, h, hd).astype(q.dtype)


def wkv6_ref(r, k, v, w, u, state=None):
    """Sequential WKV6 recurrence — see repro.models.rwkv6.wkv_sequential."""
    from repro.models.rwkv6 import wkv_sequential
    return wkv_sequential(r, k, v, w, u, state)


def rglru_ref(a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    """Sequential linear recurrence h_t = a_t h_{t-1} + b_t.
    a, b: (B, T, W); h0: (B, W). Returns h (B, T, W)."""
    def step(h, xs):
        at, bt = xs
        h = at * h + bt
        return h, h

    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    _, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                         (jnp.moveaxis(af, 1, 0), jnp.moveaxis(bf, 1, 0)))
    return jnp.moveaxis(hs, 0, 1).astype(a.dtype)
