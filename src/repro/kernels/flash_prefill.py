"""Pallas TPU flash-attention prefill kernel (causal + sliding window +
logit softcap — covers every assigned attention variant).

Grid: (B, H, NQ, NK) with NK innermost: the running-softmax scratch
persists across key blocks for a fixed query block. Causal/window block
skipping prunes key blocks wholly outside the mask, which is where the
sliding-window archs (mixtral, danube, gemma2-local) win their prefill
FLOPs back. VMEM working set per step: q (Bq, hd), k/v (Bk, hd),
acc (Bq, hd) fp32 — pick Bq=Bk=128..512 and MXU-aligned hd.
Validated in interpret mode against ref.flash_prefill_ref.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, bq: int, bk: int, nk: int, softcap: Optional[float],
            window: Optional[int], scale: float):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * bq
    k_start = ik * bk
    # causal block skip: this key block starts after the last query row
    relevant = k_start <= q_start + bq - 1
    if window is not None:
        # key block entirely below the window of every query row
        relevant &= (k_start + bk - 1) > (q_start - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                      # (Bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                      # (Bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos <= qpos
        if window is not None:
            mask &= kpos > (qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        pr = jnp.exp(s - m_cur)
        corr = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * corr + jnp.sum(pr, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            pr, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array,
                  softcap: Optional[float] = None,
                  window: Optional[int] = None,
                  block_q: int = 128, block_k: int = 128,
                  interpret: bool = True) -> jax.Array:
    """q: (B, S, H, hd); k, v: (B, S, Hkv, hd) -> (B, S, H, hd).
    S must be a multiple of the block sizes (pad upstream)."""
    b, s, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    nq, nk = s // bq, s // bk

    qh = q.transpose(0, 2, 1, 3)                                 # (B, H, S, hd)
    kh = k.transpose(0, 2, 1, 3)                                 # (B, Hkv, S, hd)
    vh = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_kernel, bq=bq, bk=bk, nk=nk, softcap=softcap,
                               window=window, scale=1.0 / math.sqrt(hd))
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((b, h, s, hd), q.dtype),
        interpret=interpret,
    )(qh, kh, vh)
    return out.transpose(0, 2, 1, 3)
