"""Pallas TPU RG-LRU linear-recurrence kernel (RecurrentGemma/Griffin).

h_t = a_t ⊙ h_{t-1} + b_t, per channel. The width dimension maps onto
vector lanes (block over W, multiples of 128); the sequence is chunked
with the carried state in VMEM scratch across the innermost grid dim.
Within a chunk the recurrence is a short fori_loop of fused vector ops —
elementwise recurrences have no MXU work, so lane-parallelism over W is
the whole game on TPU. Grid: (B, NW, NC).
Validated in interpret mode against ref.rglru_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h0_ref, o_ref, h_ref, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)                             # (C, Wb)
    b = b_ref[0].astype(jnp.float32)

    def step(i, h):
        h = a[i] * h + b[i]
        o_ref[0, i, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[0])
    h_ref[...] = h[None]


def rglru(a: jax.Array, b: jax.Array, h0: jax.Array,
          chunk: int = 128, block_w: int = 128,
          interpret: bool = True) -> jax.Array:
    """a, b: (B, T, W); h0: (B, W). Returns h: (B, T, W).
    T % chunk == 0 and W % block_w == 0 (pad upstream)."""
    bsz, t, w = a.shape
    assert t % chunk == 0 and w % block_w == 0, (t, w, chunk, block_w)
    nc, nw = t // chunk, w // block_w

    kernel = functools.partial(_kernel, chunk=chunk)
    spec = pl.BlockSpec((1, chunk, block_w), lambda bi, wi, ci: (bi, ci, wi))
    out = pl.pallas_call(
        kernel,
        grid=(bsz, nw, nc),
        in_specs=[spec, spec,
                  pl.BlockSpec((1, block_w), lambda bi, wi, ci: (bi, wi))],
        out_specs=spec,
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((bsz, t, w), a.dtype),
        interpret=interpret,
    )(a, b, h0)
    return out
