"""Pallas TPU paged-attention decode kernel (flash-decode over KV pages).

This is FLOWSERVE's hot decode path: one query token per sequence attends
over that sequence's pages of the global KV pool, with the block table
scalar-prefetched so page blocks can be DMA'd from HBM into VMEM by the
BlockSpec index_map (the TPU-native analogue of vLLM's PagedAttention
gather).

Grid: (B, Hkv, NP) — NP innermost so the running-softmax scratch carries
across a sequence's pages. Per step the kernel holds in VMEM:
    q block      (G, hd)        G = H // Hkv query heads per KV head
    k/v page     (P, hd)
    acc scratch  (G, hd) fp32 + m/l (G, 1)
For hardware efficiency pick P a multiple of 128 and hd in {64,128,256}
(MXU-aligned); G×hd tiles stay resident. Validated in interpret mode
against ref.paged_attention_ref across shape/dtype sweeps.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    # scalar-prefetch refs
    block_tables_ref,            # (B, NP) int32
    lengths_ref,                 # (B,) int32
    # blocked operands
    q_ref,                       # (1, 1, G, hd)
    k_ref,                       # (1, P, 1, hd)
    v_ref,                       # (1, P, 1, hd)
    o_ref,                       # (1, 1, G, hd)
    # scratch
    m_ref, l_ref, acc_ref,
    *, page_size: int, n_pages: int, softcap: Optional[float],
    window: Optional[int], scale: float,
):
    b, h, p = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[b]
    start = p * page_size

    @pl.when(start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                      # (G, hd)
        k = k_ref[:, :, 0, :][0].astype(jnp.float32)             # (P, hd)
        v = v_ref[:, :, 0, :][0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        pos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = pos < length
        if window is not None:
            valid &= pos > (length - 1 - window)
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[...]                                      # (G, 1)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        pr = jnp.exp(s - m_cur)
        corr = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * corr + jnp.sum(pr, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            pr, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(p == n_pages - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    block_tables: jax.Array, lengths: jax.Array,
                    softcap: Optional[float] = None,
                    window: Optional[int] = None,
                    interpret: bool = True) -> jax.Array:
    """q: (B, H, hd); k_pages/v_pages: (NP_pool, P, Hkv, hd);
    block_tables: (B, NP) int32; lengths: (B,). Returns (B, H, hd)."""
    b, h, hd = q.shape
    _, page_size, hkv, _ = k_pages.shape
    n_pages = block_tables.shape[1]
    g = h // hkv
    qh = q.reshape(b, hkv, g, hd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda bi, hi, pi, bt, ln: (bi, hi, 0, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda bi, hi, pi, bt, ln: (bt[bi, pi], 0, hi, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda bi, hi, pi, bt, ln: (bt[bi, pi], 0, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda bi, hi, pi, bt, ln: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _kernel, page_size=page_size, n_pages=n_pages, softcap=softcap,
        window=window, scale=1.0 / math.sqrt(hd))
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, hd), q.dtype),
        interpret=interpret,
    )(block_tables, lengths, qh, k_pages, v_pages)
    return out.reshape(b, h, hd)
