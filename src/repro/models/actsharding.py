"""Activation-sharding policy hook.

The model code is mesh-agnostic; the launcher installs a PartitionSpec for
inter-layer activations (the scan carry — also the per-layer remat
residual). For train_4k on the production mesh this is
P(("pod","data"), "model", None): batch over DP, sequence over TP
(Megatron-style sequence parallelism), which shrinks saved residuals 16×
and lets XLA insert the gather/reduce-scatter pair per layer.
"""
from __future__ import annotations

from typing import Optional

import jax

_ACT_SPEC: Optional[object] = None
_BLOCK_SPECS: Optional[dict] = None


def set_act_spec(spec) -> None:
    global _ACT_SPEC
    _ACT_SPEC = spec


def get_act_spec():
    return _ACT_SPEC


def constrain(x: jax.Array) -> jax.Array:
    if _ACT_SPEC is None:
        return x
    return jax.lax.with_sharding_constraint(x, _ACT_SPEC)


_TAG_SPECS: dict = {}


def set_tag_specs(specs: Optional[dict]) -> None:
    """Named constraint points (e.g. MoE dispatch tensors) installed by the
    launcher. Keys: 'moe_tokens' (G,Tg,D), 'moe_hidden' (G,E,C,F),
    'moe_out' (G,E,C,D)."""
    global _TAG_SPECS
    _TAG_SPECS = specs or {}


def _compatible(x, sharding) -> bool:
    """Every sharded dim must divide evenly (skip e.g. group=1 MoE decode)."""
    try:
        spec = sharding.spec
        mesh = sharding.mesh
    except AttributeError:
        return True
    for dim, axes in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
        if axes is None:
            continue
        axes = axes if isinstance(axes, tuple) else (axes,)
        n = 1
        for a in axes:
            n *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
        if dim % n != 0:
            return False
    return True


def constrain_tag(x: jax.Array, tag: str) -> jax.Array:
    spec = _TAG_SPECS.get(tag)
    if spec is None or not _compatible(x, spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def set_block_specs(specs: Optional[dict]) -> None:
    """Per-layer weight shardings (leading layer dim stripped). Installing
    these pins each scan iteration's weight slice to its FSDP storage
    sharding at body entry, so GSPMD gathers weights *inside* the (remat'd)
    loop — one layer live at a time — instead of hoisting an all-layer
    gather out of the scan (which OOMs MoE train cells)."""
    global _BLOCK_SPECS
    _BLOCK_SPECS = specs


def constrain_block(p, tower: str):
    if _BLOCK_SPECS is None or tower not in _BLOCK_SPECS:
        return p
    # the barrier stops loop-invariant code motion from hoisting whole-stack
    # weight converts/gathers out of the layer scan (all layers live at once)
    p = jax.lax.optimization_barrier(p)
    return jax.lax.with_sharding_constraint(p, _BLOCK_SPECS[tower])
