from repro.models.model_factory import ModelBundle, get_model, cross_entropy  # noqa: F401
