"""RWKV-6 "Finch" — attention-free time-mix with data-dependent decay.

Two formulations of the WKV6 recurrence:
  * ``wkv_sequential`` — the literal per-token recurrence (oracle; also the
    decode step).
  * ``wkv_chunked`` — chunk-parallel form: within-chunk pairwise term via
    masked matmuls in log-decay space, cross-chunk via a state scan. This is
    the MXU-friendly TPU formulation (the Pallas kernel implements the same
    schedule per (batch, head) block). ``unroll=True`` removes the chunk
    while-loop for exact cost probes.

Recurrence (per head, state S ∈ R^{hd×hd}):
    y_t = r_t · (S_{t-1} + (u ⊙ k_t) v_tᵀ)
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ
with per-channel decay w_t = exp(-exp(ŵ_t)) computed from the input
(data-dependent, the Finch contribution).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

# Clamp on per-token log-decay used inside the within-chunk matmul so the
# exp(-cumsum) factors stay in fp32 range. exp(-30) underflows anything the
# pairwise term could contribute, so this is numerically lossless at chunk
# sizes <= 64 (tested against the sequential oracle).
LOG_DECAY_CLAMP = -30.0


def wkv_sequential(r, k, v, w, u, state=None):
    """r,k,v,w: (B, T, H, hd); u: (H, hd). Returns (y, final_state).
    state: (B, H, hd, hd) mapping k-dim × v-dim."""
    b, t, h, hd = r.shape
    if state is None:
        state = jnp.zeros((b, h, hd, hd), jnp.float32)
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))

    def step(s, xs):
        rt, kt, vt, wt = xs                                   # (B,H,hd)
        kv = kt[..., :, None] * vt[..., None, :]              # (B,H,hd,hd)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (rf, kf, vf, wf))
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), state


def wkv_chunked(r, k, v, w, u, state=None, chunk: int = 64, unroll: bool = False):
    """Chunk-parallel WKV6. Same signature/semantics as wkv_sequential."""
    b, t, h, hd = r.shape
    if state is None:
        state = jnp.zeros((b, h, hd, hd), jnp.float32)
    pad = (-t) % chunk
    if pad:
        z = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    tt = t + pad
    n = tt // chunk
    shape = (b, n, chunk, h, hd)
    rc, kc, vc, wc = (x.reshape(shape).transpose(1, 0, 3, 2, 4).astype(jnp.float32)
                      for x in (r, k, v, w))                   # (n,B,H,C,hd)

    u32 = u.astype(jnp.float32)

    def body(s, xs):
        rb, kb, vb, wb = xs                                    # (B,H,C,hd)
        lw = jnp.clip(jnp.log(jnp.maximum(wb, 1e-38)), LOG_DECAY_CLAMP, 0.0)
        cum = jnp.cumsum(lw, axis=2)                           # decay from chunk start, inclusive
        # contribution of the carried-in state: r_i ⊙ Π_{j<=i-1} w_j ... note
        # state applies decays of steps 1..i-1 plus current-token is excluded
        dec_in = jnp.exp(cum - lw)                             # Π_{j<i} w_j  (B,H,C,hd)
        y_state = jnp.einsum("bhck,bhkv->bhcv", rb * dec_in, s)
        # within-chunk pairwise term, strictly lower-triangular in time
        q_side = rb * jnp.exp(cum - lw)                        # r_i Π_{j<i} w
        k_side = kb * jnp.exp(-cum)                            # k_j / Π_{j<=j} w
        scores = jnp.einsum("bhck,bhdk->bhcd", q_side, k_side)  # (B,H,C,C) c=query d=key
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        scores = jnp.where(mask, scores, 0.0)
        # current-token bonus term
        bonus = jnp.einsum("bhck,bhck->bhc", rb * u32[None, :, None, :], kb)
        y = y_state + jnp.einsum("bhcd,bhdv->bhcv", scores, vb) + bonus[..., None] * vb
        # state update across the chunk
        total = cum[:, :, -1:, :]                              # Σ log w over chunk
        k_dec = kb * jnp.exp(total - cum)                      # k_j Π_{l>j} w_l
        s = jnp.exp(total[:, :, 0, :])[..., None] * s + jnp.einsum(
            "bhck,bhcv->bhkv", k_dec, vb)
        return s, y

    state, ys = jax.lax.scan(body, state, (rc, kc, vc, wc),
                             unroll=n if unroll else 1)
    ys = ys.transpose(1, 0, 3, 2, 4).reshape(b, tt, h, hd)[:, :t]
    return ys.astype(r.dtype), state


# ---------------------------------------------------------------------------
# Full RWKV6 block (time-mix + channel-mix) parameters and application
# ---------------------------------------------------------------------------

LORA_RANK = 32


def init_rwkv_block(key: jax.Array, d: int, f: int, head_dim: int, dtype=jnp.bfloat16) -> dict:
    h = d // head_dim
    ks = jax.random.split(key, 12)
    s = 1.0 / math.sqrt(d)
    lr = LORA_RANK
    return {
        # time-mix
        "mix_base": jnp.full((5, d), 0.5, jnp.float32),  # r,k,v,w,g static lerp
        "mix_lora_a": (jax.random.normal(ks[0], (d, lr)) * s).astype(dtype),
        "mix_lora_b": (jax.random.normal(ks[1], (5, lr, d)) * 0.01).astype(dtype),
        "wr": (jax.random.normal(ks[2], (d, d)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[3], (d, d)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[4], (d, d)) * s).astype(dtype),
        "wg": (jax.random.normal(ks[5], (d, d)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[6], (d, d)) * s).astype(dtype),
        "decay_base": jnp.full((d,), -4.0, jnp.float32),
        "decay_lora_a": (jax.random.normal(ks[7], (d, lr)) * s).astype(dtype),
        "decay_lora_b": (jax.random.normal(ks[8], (lr, d)) * 0.01).astype(dtype),
        "bonus_u": jnp.zeros((h, head_dim), jnp.float32),
        "ln_x": jnp.ones((d,), jnp.float32),  # per-head group-norm scale
        # channel-mix
        "cm_mix": jnp.full((2, d), 0.5, jnp.float32),
        "cm_k": (jax.random.normal(ks[9], (d, f)) * s).astype(dtype),
        "cm_v": (jax.random.normal(ks[10], (f, d)) * (1.0 / math.sqrt(f))).astype(dtype),
        "cm_r": (jax.random.normal(ks[11], (d, d)) * s).astype(dtype),
    }


def _token_shift(x, last):
    """shifted[t] = x[t-1]; position 0 takes `last` (carried across calls)."""
    shifted = jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)
    return shifted


def rwkv_time_mix(p: dict, x: jax.Array, head_dim: int, state, last_x,
                  chunked: bool = True, chunk: int = 64, unroll: bool = False,
                  n_valid=None):
    """x: (B,T,D). state: (B,H,hd,hd). last_x: (B,D) previous token input.
    Returns (y, new_state, new_last_x).

    ``n_valid`` (static or traced scalar) marks positions >= n_valid as
    padding: their recurrence steps become exact identities (w -> 1,
    k -> 0, so S_t = diag(1)S + 0 = S) and new_last_x gathers at
    n_valid-1 — the bucketed-prefill contract (DESIGN.md §12)."""
    b, t, d = x.shape
    h = d // head_dim
    xs = _token_shift(x, last_x)
    delta = (xs - x).astype(jnp.float32)
    # data-dependent lerp (ddlerp): mix = base + lora(x)
    lora = jnp.einsum("btd,dr->btr", x, p["mix_lora_a"])
    mixes = p["mix_base"][:, None, None, :] + jnp.einsum(
        "btr,mrd->mbtd", jax.nn.tanh(lora.astype(jnp.float32)).astype(x.dtype),
        p["mix_lora_b"]).astype(jnp.float32)
    xr, xk, xv, xw, xg = (x.astype(jnp.float32) + delta * mixes[i] for i in range(5))
    cast = lambda a: a.astype(x.dtype)
    r = jnp.einsum("btd,de->bte", cast(xr), p["wr"]).reshape(b, t, h, head_dim)
    k = jnp.einsum("btd,de->bte", cast(xk), p["wk"]).reshape(b, t, h, head_dim)
    v = jnp.einsum("btd,de->bte", cast(xv), p["wv"]).reshape(b, t, h, head_dim)
    g = jax.nn.silu(jnp.einsum("btd,de->bte", cast(xg), p["wg"]))
    dec = p["decay_base"] + jnp.einsum(
        "btd,dr,re->bte", cast(xw), p["decay_lora_a"], p["decay_lora_b"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(dec)).reshape(b, t, h, head_dim)  # (0,1) per channel
    if n_valid is not None:
        # same padding constants wkv_chunked uses for its own chunk tail
        valid = (jnp.arange(t) < n_valid)[None, :, None, None]
        w = jnp.where(valid, w, 1.0)
        k = jnp.where(valid, k, 0.0)

    fn = wkv_chunked if chunked else wkv_sequential
    if chunked:
        y, state = fn(r, k, v, w.astype(r.dtype), p["bonus_u"], state, chunk=chunk, unroll=unroll)
    else:
        y, state = fn(r, k, v, w.astype(r.dtype), p["bonus_u"], state)
    # per-head group norm then gate
    y32 = y.astype(jnp.float32)
    mu = jnp.mean(y32, axis=-1, keepdims=True)
    var = jnp.var(y32, axis=-1, keepdims=True)
    y32 = (y32 - mu) * jax.lax.rsqrt(var + 1e-5)
    y = (y32.reshape(b, t, d) * p["ln_x"]).astype(x.dtype) * g
    y = jnp.einsum("btd,de->bte", y, p["wo"])
    return y, state, _last_valid(x, n_valid)


def _last_valid(x, n_valid):
    """x[:, n_valid-1, :] with a possibly-traced n_valid (the carried
    last-token input must come from the last REAL position, not the pad)."""
    if n_valid is None:
        return x[:, -1, :]
    return jax.lax.dynamic_slice_in_dim(x, n_valid - 1, 1, axis=1)[:, 0, :]


def rwkv_channel_mix(p: dict, x: jax.Array, last_x, n_valid=None):
    xs = _token_shift(x, last_x)
    delta = (xs - x).astype(jnp.float32)
    xk = (x.astype(jnp.float32) + delta * p["cm_mix"][0]).astype(x.dtype)
    xr = (x.astype(jnp.float32) + delta * p["cm_mix"][1]).astype(x.dtype)
    kk = jnp.square(jax.nn.relu(jnp.einsum("btd,df->btf", xk, p["cm_k"])))
    rr = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["cm_r"]).astype(jnp.float32)).astype(x.dtype)
    return rr * jnp.einsum("btf,fd->btd", kk, p["cm_v"]), _last_valid(x, n_valid)
