"""Mixture-of-Experts layer (Mixtral / Granite style, top-k routing).

Compiled-path formulation: capacity-bounded gather → per-expert matmul →
scatter-add combine. This keeps FLOPs at top_k·capacity_factor × the dense
FFN cost (no dense-all-experts blowup) while remaining fully static-shaped
so GSPMD can partition it. Tokens are grouped (``groups`` = number of data
shards) and capacity is enforced per (group, expert) — the GShard policy.
Tokens routed beyond an expert's capacity are dropped for that expert
(contribute only via their other top-k choices), standard for TPU MoE.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig


def init_moe(key: jax.Array, d: int, cfg: MoEConfig, act: str, dtype=jnp.bfloat16) -> dict:
    kr, kg, ku, kd = jax.random.split(key, 4)
    e, f = cfg.n_experts, cfg.d_expert
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {
        "router": (jax.random.normal(kr, (d, e)) * s_in).astype(jnp.float32),
        "w_up": (jax.random.normal(ku, (e, d, f)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(kd, (e, f, d)) * s_out).astype(dtype),
    }
    if act in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(kg, (e, d, f)) * s_in).astype(dtype)
    return p


def moe_capacity(tokens_per_group: int, cfg: MoEConfig) -> int:
    cap = int(math.ceil(tokens_per_group * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    return max(8, ((cap + 7) // 8) * 8)  # pad to a multiple of 8


def moe_apply(p: dict, x: jax.Array, cfg: MoEConfig, act: str,
              groups: int = 1) -> jax.Array:
    """x: (B, S, D) -> (B, S, D). ``groups`` should equal the number of data
    shards so capacity selection stays shard-local (no global sort)."""
    b, s, d = x.shape
    t = b * s
    assert t % groups == 0, (t, groups)
    tg = t // groups
    e, k = cfg.n_experts, cfg.top_k
    cap = min(moe_capacity(tg, cfg), tg)

    from repro.models import actsharding as AS
    xt = AS.constrain_tag(x.reshape(groups, tg, d), "moe_tokens")
    # router matmul in model dtype (casting xt to f32 here makes XLA keep an
    # f32 copy of the token tensor that the dispatch gather then reads,
    # promoting every downstream expert tensor — and the weight stack — to
    # f32); softmax/top-k run in f32 on the small (G,Tg,E) logits.
    logits = jnp.einsum("gtd,de->gte", xt,
                        p["router"].astype(xt.dtype)).astype(jnp.float32)
    # top-k selection, combine weights = softmax over the selected k logits
    top_logits, top_idx = jax.lax.top_k(logits, k)                  # (G,Tg,k)
    top_w = jax.nn.softmax(top_logits, axis=-1)                     # (G,Tg,k)
    # per-(token, expert) combine weight
    onehot = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)          # (G,Tg,k,E)
    w_te = jnp.einsum("gtk,gtke->gte", top_w, onehot)               # (G,Tg,E)

    # capacity enforcement: each expert keeps its top-`cap` tokens by weight
    scores = jnp.swapaxes(w_te, 1, 2)                               # (G,E,Tg)
    sel_scores, sel_tok = jax.lax.top_k(scores, cap)                # (G,E,cap)
    keep = sel_scores > 0.0                                         # dropped / padding slots

    # gather tokens: (G,E,cap,D)
    xg = jnp.take_along_axis(xt[:, None], sel_tok[..., None], axis=2)
    xg = AS.constrain_tag(xg * keep[..., None].astype(xg.dtype), "moe_out")

    # expert FFN — hidden tensors pinned to (dp, -, -, model)
    up = AS.constrain_tag(jnp.einsum("gecd,edf->gecf", xg, p["w_up"]),
                          "moe_hidden")
    if act in ("swiglu", "geglu"):
        gate = AS.constrain_tag(jnp.einsum("gecd,edf->gecf", xg, p["w_gate"]),
                                "moe_hidden")
        g = jax.nn.silu(gate) if act == "swiglu" else jax.nn.gelu(gate, approximate=True)
        h = g * up
    else:
        h = jnp.square(jax.nn.relu(up))
    y = jnp.einsum("gecf,efd->gecd", h, p["w_down"])                # (G,E,cap,D)
    y = AS.constrain_tag(y * (sel_scores * keep)[..., None].astype(y.dtype),
                         "moe_out")

    # scatter-add back to token order
    out = jnp.zeros((groups, tg, d), y.dtype)
    gi = jnp.arange(groups)[:, None, None]
    out = out.at[gi, sel_tok].add(y)
    return out.reshape(b, s, d)


def moe_aux_loss(p: dict, x: jax.Array, cfg: MoEConfig) -> jax.Array:
    """Switch-style load-balancing auxiliary loss (used in fine-tune jobs)."""
    b, s, d = x.shape
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    _, top_idx = jax.lax.top_k(logits, cfg.top_k)
    frac_routed = jnp.mean(jax.nn.one_hot(top_idx, cfg.n_experts), axis=(0, 1, 2))
    frac_prob = jnp.mean(probs, axis=(0, 1))
    return cfg.n_experts * jnp.sum(frac_routed * frac_prob)
