"""Opt-in performance features (the §Perf hillclimb knobs).

Baseline (paper-faithful reproduction) keeps every flag off; the optimized
configuration is recorded separately in EXPERIMENTS.md §Perf. All flags
preserve numerics (validated against the naive paths in tests).
"""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class PerfFlags:
    # decode: SWA archs slice the KV cache to the attention window instead
    # of reading (and masking) the full context — bytes ∝ window, not S.
    windowed_decode: bool = False
    # prefill: SWA attention over a gathered diagonal band instead of the
    # full-causal chunk scan — FLOPs ∝ S·(window+Q) instead of S².
    banded_swa_prefill: bool = False
    # train: cross-entropy computed in sequence chunks (caps logits peak)
    chunked_ce: bool = False
    # decode: rotating KV buffer of ring_len(cfg) slots for windowed archs —
    # memory AND footprint ∝ window; shard-local by construction (the
    # windowed_decode gather variant forced a KV all-gather — refuted).
    ring_buffer_decode: bool = False


_FLAGS = PerfFlags()


def get() -> PerfFlags:
    return _FLAGS


def set_flags(**kw) -> PerfFlags:
    global _FLAGS
    _FLAGS = replace(_FLAGS, **kw)
    return _FLAGS


def reset() -> None:
    global _FLAGS
    _FLAGS = PerfFlags()
