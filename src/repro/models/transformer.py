"""Config-driven model zoo: init / forward / prefill / decode for every
assigned architecture family.

Layer execution strategies (see DESIGN.md §5 and the scan-cost note):
  * homogeneous towers (dense / moe / rwkv / enc-dec / vlm groups) run under
    ``jax.lax.scan`` over stacked layer params → compact HLO, fast compiles,
    correct memory analysis on the production mesh;
  * heterogeneous towers (recurrentgemma's rglru/attn mix) unroll a static
    Python loop (26 cheap blocks);
  * exact-FLOPs cost probes call the per-block functions directly
    (``*_block_apply``) with unrolled chunked attention.

Caches are dense stacked arrays (engine-level paging lives in
repro.engine.kv_cache; the compiled step always sees gathered pages).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as G
from repro.models import rwkv6 as R

GLOBAL_WINDOW = 2 ** 30  # sentinel "window" meaning full causal attention


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _init_attn_block(cfg: ModelConfig, key, dtype, cross: bool = False) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "ln1": L.init_norm(cfg.d_model, cfg.norm),
        "attn": L.init_attn(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                            cfg.head_dim, cfg.qk_norm, dtype),
    }
    if cross:
        p["gate_attn"] = jnp.zeros((), jnp.float32)
        p["gate_mlp"] = jnp.zeros((), jnp.float32)
    p["ln2"] = L.init_norm(cfg.d_model, cfg.norm)
    if cfg.moe is not None and not cross:
        p["moe"] = M.init_moe(k2, cfg.d_model, cfg.moe, cfg.mlp_act, dtype)
    else:
        p["mlp"] = L.init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.mlp_act, dtype)
    if cfg.post_norms:
        p["ln1_post"] = L.init_norm(cfg.d_model, cfg.norm)
        p["ln2_post"] = L.init_norm(cfg.d_model, cfg.norm)
    return p


def _init_rwkv_block(cfg: ModelConfig, key, dtype) -> dict:
    return {
        "ln1": L.init_norm(cfg.d_model, cfg.norm),
        "tm": R.init_rwkv_block(key, cfg.d_model, cfg.d_ff, cfg.rwkv.head_dim, dtype),
        "ln2": L.init_norm(cfg.d_model, cfg.norm),
    }


def _init_rglru_block(cfg: ModelConfig, key, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_norm(cfg.d_model, cfg.norm),
        "rec": G.init_rglru_block(k1, cfg.d_model, cfg.rglru.lru_width,
                                  cfg.rglru.conv1d_width, dtype),
        "ln2": L.init_norm(cfg.d_model, cfg.norm),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_act, dtype),
    }


def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.bfloat16) -> Dict[str, Any]:
    keys = jax.random.split(key, cfg.n_layers + 8)
    vp = cfg.padded_vocab
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(keys[-1], (vp, cfg.d_model)) * 0.02).astype(dtype),
        "final_norm": L.init_norm(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(keys[-2], (cfg.d_model, vp))
                             * (1.0 / math.sqrt(cfg.d_model))).astype(dtype)

    kinds = cfg.layer_kinds()
    if cfg.attn_kind == "rwkv":
        params["blocks"] = _stack([_init_rwkv_block(cfg, keys[i], dtype)
                                   for i in range(cfg.n_layers)])
    elif cfg.attn_kind == "hybrid_rglru":
        # heterogeneous: keep per-kind lists (unrolled execution)
        params["rglru_blocks"] = [
            _init_rglru_block(cfg, keys[i], dtype)
            for i, k in enumerate(kinds) if k == "rglru"]
        params["attn_blocks"] = [
            _init_attn_block(cfg, keys[i], dtype)
            for i, k in enumerate(kinds) if k.startswith("attn")]
    else:
        params["blocks"] = _stack([_init_attn_block(cfg, keys[i], dtype)
                                   for i in range(cfg.n_layers)])

    if cfg.vision is not None:
        n_cross = len(cfg.cross_attn_layers())
        params["cross_blocks"] = _stack([
            _init_attn_block(cfg, keys[-3 - i], dtype, cross=True)
            for i in range(n_cross)])
    if cfg.encoder is not None:
        ek = jax.random.split(keys[-4], cfg.encoder.n_layers)
        params["enc_blocks"] = _stack([_init_attn_block(cfg, ek[i], dtype)
                                       for i in range(cfg.encoder.n_layers)])
        params["enc_final_norm"] = L.init_norm(cfg.d_model, cfg.norm)
        dk = jax.random.split(keys[-5], cfg.n_layers)
        params["cross_blocks"] = _stack([
            {"ln": L.init_norm(cfg.d_model, cfg.norm),
             "attn": L.init_attn(dk[i], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.head_dim, False, dtype)}
            for i in range(cfg.n_layers)])
    return params


# ---------------------------------------------------------------------------
# Per-layer window schedule (traced-friendly: GLOBAL_WINDOW == full causal)
# ---------------------------------------------------------------------------


def window_schedule(cfg: ModelConfig) -> jnp.ndarray:
    win = []
    for kind in cfg.layer_kinds():
        if kind == "attn_local":
            win.append(cfg.window or GLOBAL_WINDOW)
        else:
            win.append(GLOBAL_WINDOW)
    return jnp.asarray(win, jnp.int32)


# ---------------------------------------------------------------------------
# Block applications (shared by scan bodies, unrolled loops and cost probes)
# ---------------------------------------------------------------------------


def _self_attention(cfg: ModelConfig, q, k, v, q_pos, k_pos, window,
                    attn_impl: str, unroll_probe: bool, causal=True):
    from repro.models import actsharding as AS
    from repro.models import perf_flags as PF
    sk = k.shape[1]
    if attn_impl == "naive" or (attn_impl == "auto" and sk <= 2048):
        mask = L.causal_mask(q_pos, k_pos) if causal else (k_pos < GLOBAL_WINDOW)[:, None, :]
        if causal:
            mask &= k_pos[:, None, :] > (q_pos[:, :, None] - window)
        return L.attention(q, k, v, mask, cfg.attn_logit_softcap)
    # context-parallel attention for archs whose head count cannot shard on
    # the 16-way model axis (granite 24H, recurrentgemma 10H): shard the
    # query rows over `model`, keep K/V whole — each shard computes 1/16 of
    # the rows instead of replicating the full quadratic (§Perf).
    q = AS.constrain_tag(q, "attn_q_seq")
    # banded SWA attention: FLOPs/bytes ∝ S·(window+Q) instead of S²
    if (PF.get().banded_swa_prefill and cfg.attn_kind == "swa" and causal
            and cfg.window is not None and cfg.window + 1024 < sk
            and q.shape[1] == sk):
        o = L.banded_swa_attention(q, k, v, cfg.window,
                                   softcap=cfg.attn_logit_softcap)
    else:
        chunk = min(1024, sk)
        o = L.flash_attention(q, k, v, q_pos, k_pos, window=window,
                              softcap=cfg.attn_logit_softcap, chunk=chunk,
                              unroll=unroll_probe, causal=causal)
    return AS.constrain_tag(o, "attn_q_seq")


def attn_block_apply(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array,
                     window, kv_write=None, attn_impl: str = "auto",
                     unroll_probe: bool = False) -> Tuple[jax.Array, Optional[tuple]]:
    """One pre-norm attention block. ``kv_write``: None for self-contained
    fwd (train/prefill-from-scratch); or a dict {'k','v','k_pos','write_at'}
    carrying the dense cache slice for this layer (decode / cached prefill).
    Returns (x_out, updated (k, v) or None)."""
    h = L.apply_norm(x, p["ln1"], cfg.norm)
    q, k_new, v_new = L.attn_qkv(p["attn"], h, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.head_dim, positions, cfg.rope_theta, cfg.qk_norm)
    updated = None
    if kv_write is None:
        k_all, v_all, k_pos = k_new, v_new, positions
    else:
        bidx = jnp.arange(x.shape[0])[:, None]
        widx = kv_write["write_at"][:, None] + jnp.arange(x.shape[1])[None, :]
        k_all = kv_write["k"].at[bidx, widx].set(k_new)
        v_all = kv_write["v"].at[bidx, widx].set(v_new)
        k_pos = kv_write["k_pos"]
        updated = (k_all, v_all)
    o = _self_attention(cfg, q, k_all, v_all, positions, k_pos,
                        window, attn_impl, unroll_probe)
    o = L.attn_out(p["attn"], o)
    if cfg.post_norms:
        o = L.apply_norm(o, p["ln1_post"], cfg.norm)
    x = x + o
    h = L.apply_norm(x, p["ln2"], cfg.norm)
    if "moe" in p:
        m = M.moe_apply(p["moe"], h, cfg.moe, cfg.mlp_act, groups=_moe_groups(h))
    else:
        m = L.mlp_apply(p["mlp"], h, cfg.mlp_act)
    if cfg.post_norms:
        m = L.apply_norm(m, p["ln2_post"], cfg.norm)
    return x + m, updated


def _moe_groups(h: jax.Array) -> int:
    # one capacity group per data shard; tokens per group must stay >= 64
    t = h.shape[0] * h.shape[1]
    for g in (16, 8, 4, 2, 1):
        if t % g == 0 and t // g >= 64:
            return g
    return 1


def cross_block_apply(cfg: ModelConfig, p: dict, x: jax.Array, mem_k: jax.Array,
                      mem_v: jax.Array, gated: bool) -> jax.Array:
    """Cross-attention block. mem_k/mem_v: (B, P, Hkv, hd) precomputed from
    the modality memory (vision patches / encoder output)."""
    from repro.models import actsharding as AS
    h = L.apply_norm(x, p["ln1"] if "ln1" in p else p["ln"], cfg.norm)
    b, s, _ = h.shape
    q = jnp.einsum("bsd,dh->bsh", h, p["attn"]["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    q = AS.constrain_tag(q, "cross_q")
    o = L.attention(q, mem_k, mem_v, None, cfg.attn_logit_softcap)
    o = AS.constrain_tag(o, "cross_q")
    o = L.attn_out(p["attn"], o)
    if gated:
        x = x + jnp.tanh(p["gate_attn"]).astype(o.dtype) * o
        h = L.apply_norm(x, p["ln2"], cfg.norm)
        m = L.mlp_apply(p["mlp"], h, cfg.mlp_act)
        x = x + jnp.tanh(p["gate_mlp"]).astype(m.dtype) * m
        return x
    return x + o


def memory_kv(cfg: ModelConfig, p_attn: dict, mem: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Project modality memory into (k, v) for cross attention (no rope)."""
    b, s, _ = mem.shape
    k = jnp.einsum("bsd,dh->bsh", mem, p_attn["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = jnp.einsum("bsd,dh->bsh", mem, p_attn["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    return k, v


def rwkv_block_apply(cfg: ModelConfig, p: dict, x, state, last_tm, last_cm,
                     chunked=True, unroll_probe=False, n_valid=None):
    h = L.apply_norm(x, p["ln1"], cfg.norm)
    y, state, last_tm = R.rwkv_time_mix(p["tm"], h, cfg.rwkv.head_dim, state, last_tm,
                                        chunked=chunked, unroll=unroll_probe,
                                        n_valid=n_valid)
    x = x + y
    h = L.apply_norm(x, p["ln2"], cfg.norm)
    y, last_cm = R.rwkv_channel_mix(p["tm"], h, last_cm, n_valid=n_valid)
    return x + y, state, last_tm, last_cm


def rglru_block_apply(cfg: ModelConfig, p: dict, x, h0, conv_state, decode=False,
                      n_valid=None):
    h = L.apply_norm(x, p["ln1"], cfg.norm)
    y, h0, conv_state = G.rglru_block_apply(p["rec"], h, h0, conv_state, decode=decode,
                                            n_valid=n_valid)
    x = x + y
    h = L.apply_norm(x, p["ln2"], cfg.norm)
    return x + L.mlp_apply(p["mlp"], h, cfg.mlp_act), h0, conv_state


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed(cfg: ModelConfig, params, tokens: jax.Array) -> jax.Array:
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def unembed(cfg: ModelConfig, params, x: jax.Array) -> jax.Array:
    x = L.apply_norm(x, params["final_norm"], cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    if cfg.final_logit_softcap is not None:
        logits = (cfg.final_logit_softcap
                  * jnp.tanh(logits.astype(jnp.float32) / cfg.final_logit_softcap)).astype(logits.dtype)
    return logits


# ---------------------------------------------------------------------------
# Forward (teacher-forced, used by train / prefill-from-scratch)
# ---------------------------------------------------------------------------


def _maybe_remat(fn, remat: bool):
    """Per-layer rematerialization: wraps a scan body (or an unrolled block)
    so bwd saves only the layer-boundary activations — which the launcher
    additionally sequence-shards via actsharding.constrain."""
    return jax.checkpoint(fn) if remat else fn


def forward(cfg: ModelConfig, params, tokens: jax.Array,
            positions: Optional[jax.Array] = None,
            vision_embeds: Optional[jax.Array] = None,
            frames: Optional[jax.Array] = None,
            attn_impl: str = "auto", scan_layers: bool = True,
            unroll_probe: bool = False, remat: bool = False) -> jax.Array:
    """tokens: (B, S) -> logits (B, S, padded_vocab)."""
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = embed(cfg, params, tokens)

    if cfg.attn_kind == "rwkv":
        x = _rwkv_tower(cfg, params, x, scan_layers, unroll_probe, remat)
    elif cfg.attn_kind == "hybrid_rglru":
        x = _rglru_tower(cfg, params, x, positions, attn_impl, unroll_probe, remat)
    elif cfg.vision is not None and vision_embeds is not None:
        x = _vlm_tower(cfg, params, x, positions, vision_embeds, attn_impl,
                       unroll_probe, remat)
    elif cfg.encoder is not None:
        assert frames is not None, "enc-dec model needs frame embeddings"
        mem = encode(cfg, params, frames, attn_impl, scan_layers, unroll_probe, remat)
        x = _decoder_tower(cfg, params, x, positions, mem, attn_impl,
                           scan_layers, unroll_probe, remat)
    else:
        x = _dense_tower(cfg, params, x, positions, attn_impl, scan_layers,
                         unroll_probe, remat)
    return unembed(cfg, params, x)


def _dense_tower(cfg, params, x, positions, attn_impl, scan_layers,
                 unroll_probe, remat=False):
    from repro.models import actsharding as AS
    wins = window_schedule(cfg)

    def block(h, p, w):
        p = AS.constrain_block(p, "blocks")
        h, _ = attn_block_apply(cfg, p, h, positions, w, None, attn_impl,
                                unroll_probe)
        return AS.constrain(h)

    blk = _maybe_remat(block, remat)
    if not scan_layers:
        for i in range(cfg.n_layers):
            p = jax.tree.map(lambda a: a[i], params["blocks"])
            x = blk(x, p, wins[i])
        return x

    def body(h, xs):
        p, w = xs
        return blk(h, p, w), None

    x, _ = jax.lax.scan(body, AS.constrain(x), (params["blocks"], wins))
    return x


def _rwkv_tower(cfg, params, x, scan_layers, unroll_probe, remat=False):
    from repro.models import actsharding as AS
    b, s, d = x.shape
    h = cfg.d_model // cfg.rwkv.head_dim
    zeros_state = jnp.zeros((b, h, cfg.rwkv.head_dim, cfg.rwkv.head_dim), jnp.float32)
    zeros_last = jnp.zeros((b, d), x.dtype)

    def block(hid, p):
        p = AS.constrain_block(p, "blocks")
        hid, _, _, _ = rwkv_block_apply(cfg, p, hid, zeros_state, zeros_last,
                                        zeros_last, True, unroll_probe)
        return AS.constrain(hid)

    blk = _maybe_remat(block, remat)

    def body(hid, p):
        return blk(hid, p), None

    if scan_layers:
        x, _ = jax.lax.scan(body, AS.constrain(x), params["blocks"])
    else:
        for i in range(cfg.n_layers):
            x = blk(x, jax.tree.map(lambda a: a[i], params["blocks"]))
    return x


def _rglru_tower(cfg, params, x, positions, attn_impl, unroll_probe, remat=False):
    from repro.models import actsharding as AS
    b = x.shape[0]
    w = cfg.rglru.lru_width
    cw = cfg.rglru.conv1d_width

    def rec_block(h, p):
        h, _, _ = rglru_block_apply(cfg, p, h,
                                    jnp.zeros((b, w), jnp.float32),
                                    jnp.zeros((b, cw - 1, w), x.dtype))
        return AS.constrain(h)

    def att_block(h, p):
        h, _ = attn_block_apply(cfg, p, h, positions,
                                jnp.int32(cfg.window or GLOBAL_WINDOW),
                                None, attn_impl, unroll_probe)
        return AS.constrain(h)

    rec_blk = _maybe_remat(rec_block, remat)
    att_blk = _maybe_remat(att_block, remat)
    kinds = cfg.layer_kinds()
    period = cfg.rglru.recurrent_per_attn + 1
    n_periods = len(kinds) // period
    # Scan over the repeating [rglru × r, attn] period: keeps the HLO (and
    # SPMD-partitioner time) ~n_periods× smaller than a full unroll, which
    # is what makes the 512-device train compile tractable. The remainder
    # layers (26 = 8·3 + 2 for recurrentgemma) run unrolled.
    scan_ok = (n_periods >= 2
               and all(kinds[i * period: (i + 1) * period]
                       == kinds[:period] for i in range(n_periods)))
    if not scan_ok:
        ri = ai = 0
        for kind in kinds:
            if kind == "rglru":
                x = rec_blk(x, params["rglru_blocks"][ri])
                ri += 1
            else:
                x = att_blk(x, params["attn_blocks"][ai])
                ai += 1
        return x

    n_rec_in = sum(1 for k in kinds[:period] if k == "rglru")
    rec_stack = _stack([_stack(params["rglru_blocks"][i * n_rec_in:
                                                      (i + 1) * n_rec_in])
                        for i in range(n_periods)])
    att_stack = _stack(params["attn_blocks"][:n_periods])

    def period_body(h, xs):
        p_rec, p_att = xs
        for j in range(n_rec_in):
            h = rec_blk(h, jax.tree.map(lambda a: a[j], p_rec))
        h = att_blk(h, p_att)
        return h, None

    x, _ = jax.lax.scan(period_body, x, (rec_stack, att_stack))
    # remainder layers (beyond the last full period)
    ri = n_periods * n_rec_in
    ai = n_periods
    for kind in kinds[n_periods * period:]:
        if kind == "rglru":
            x = rec_blk(x, params["rglru_blocks"][ri])
            ri += 1
        else:
            x = att_blk(x, params["attn_blocks"][ai])
            ai += 1
    return x


def _vlm_tower(cfg, params, x, positions, vision_embeds, attn_impl,
               unroll_probe, remat=False):
    from repro.models import actsharding as AS
    every = cfg.vision.cross_attn_every
    n_groups = cfg.n_layers // every
    wins = window_schedule(cfg).reshape(n_groups, every)
    grouped = jax.tree.map(
        lambda a: a.reshape(n_groups, every, *a.shape[1:]), params["blocks"])

    def group(h, pg, wg, pc):
        def inner(h2, xs2):
            p, wv = xs2
            p = AS.constrain_block(p, "blocks")
            h2, _ = attn_block_apply(cfg, p, h2, positions, wv, None,
                                     attn_impl, unroll_probe)
            return AS.constrain(h2), None

        h, _ = jax.lax.scan(inner, h, (pg, wg))
        mk, mv = memory_kv(cfg, pc["attn"], vision_embeds)
        h = cross_block_apply(cfg, pc, h, mk, mv, gated=True)
        return AS.constrain(h)

    grp = _maybe_remat(group, remat)

    def group_body(h, xs):
        pg, wg, pc = xs
        return grp(h, pg, wg, pc), None

    x, _ = jax.lax.scan(group_body, AS.constrain(x),
                        (grouped, wins, params["cross_blocks"]))
    return x


def encode(cfg, params, frames, attn_impl="auto", scan_layers=True,
           unroll_probe=False, remat=False):
    """Bidirectional encoder over precomputed frame embeddings (B, F, D)."""
    from repro.models import actsharding as AS
    b, f, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32)[None], (b, f))
    x = frames

    def block(h, p):
        p = AS.constrain_block(p, "enc_blocks")
        hh = L.apply_norm(h, p["ln1"], cfg.norm)
        q, k, v = L.attn_qkv(p["attn"], hh, cfg.n_heads, cfg.n_kv_heads,
                             cfg.head_dim, pos, cfg.rope_theta, cfg.qk_norm)
        o = _self_attention(cfg, q, k, v, pos, pos, None, attn_impl,
                            unroll_probe, causal=False)
        h = h + L.attn_out(p["attn"], o)
        hh = L.apply_norm(h, p["ln2"], cfg.norm)
        return AS.constrain(h + L.mlp_apply(p["mlp"], hh, cfg.mlp_act))

    blk = _maybe_remat(block, remat)

    def body(h, p):
        return blk(h, p), None

    if scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    else:
        for i in range(cfg.encoder.n_layers):
            x = blk(x, jax.tree.map(lambda a: a[i], params["enc_blocks"]))
    return L.apply_norm(x, params["enc_final_norm"], cfg.norm)


def _decoder_tower(cfg, params, x, positions, mem, attn_impl, scan_layers,
                   unroll_probe, remat=False):
    from repro.models import actsharding as AS

    def block(h, p, pc):
        p = AS.constrain_block(p, "blocks")
        h, _ = attn_block_apply(cfg, p, h, positions, GLOBAL_WINDOW, None,
                                attn_impl, unroll_probe)
        mk, mv = memory_kv(cfg, pc["attn"], mem)
        return AS.constrain(cross_block_apply(cfg, pc, h, mk, mv, gated=False))

    blk = _maybe_remat(block, remat)

    def body(h, xs):
        p, pc = xs
        return blk(h, p, pc), None

    if scan_layers:
        x, _ = jax.lax.scan(body, AS.constrain(x),
                            (params["blocks"], params["cross_blocks"]))
    else:
        for i in range(cfg.n_layers):
            x = blk(x, jax.tree.map(lambda a: a[i], params["blocks"]),
                    jax.tree.map(lambda a: a[i], params["cross_blocks"]))
    return x
