"""Serving entry points: cache init, prefill, decode_step — per family.

Sharding-aware design decisions (DESIGN.md §5):
  * decode uses *naive* masked attention (Sq=1 ⇒ scores are (B,H,1,Sk),
    tiny) so GSPMD can shard the KV sequence dim over the `model` axis and
    lower softmax/contraction reductions to psum — the flash-decode pattern
    expressed at the XLA level.
  * prefill computes attention from the *fresh* k/v activations (flash,
    chunk-scanned, no sharding conflict) and scatters k/v into the
    seq-sharded cache as a separate pure data movement.
  * caches are dense stacked arrays: (L, B, Smax, Hkv, hd). Engine-level
    paging (RTC block tables) maps pages onto these slots.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.transformer import GLOBAL_WINDOW

Cache = Dict[str, Any]


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------


def attn_layer_count(cfg: ModelConfig) -> int:
    return sum(1 for k in cfg.layer_kinds() if k.startswith("attn"))


def ring_len(cfg: ModelConfig, align: int = 256) -> int:
    """Ring-buffer length for windowed archs: window + one aligned chunk of
    slack (so the mesh can shard the ring dim 256 ways)."""
    assert cfg.window is not None
    return ((cfg.window + align + align - 1) // align) * align


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, ring: bool = False) -> Cache:
    """Dense cache sized for `max_len` tokens of context. With ``ring=True``
    (windowed archs only) the attention cache is a rotating buffer of
    ring_len(cfg) slots — decode memory ∝ window, not context (§Perf)."""
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    cache: Cache = {"length": jnp.zeros((batch,), jnp.int32)}
    la = attn_layer_count(cfg)
    s_alloc = max_len
    if ring:
        assert cfg.attn_kind in ("swa", "hybrid_rglru"), cfg.attn_kind
        s_alloc = min(max_len, ring_len(cfg))
    if la:
        cache["k"] = jnp.zeros((la, batch, s_alloc, hkv, hd), dtype)
        cache["v"] = jnp.zeros((la, batch, s_alloc, hkv, hd), dtype)
    if cfg.attn_kind == "rwkv":
        h = cfg.d_model // cfg.rwkv.head_dim
        cache["state"] = jnp.zeros((cfg.n_layers, batch, h, cfg.rwkv.head_dim,
                                    cfg.rwkv.head_dim), jnp.float32)
        cache["last_tm"] = jnp.zeros((cfg.n_layers, batch, cfg.d_model), dtype)
        cache["last_cm"] = jnp.zeros((cfg.n_layers, batch, cfg.d_model), dtype)
    if cfg.attn_kind == "hybrid_rglru":
        nr = cfg.n_layers - la
        w, cw = cfg.rglru.lru_width, cfg.rglru.conv1d_width
        cache["h"] = jnp.zeros((nr, batch, w), jnp.float32)
        cache["conv"] = jnp.zeros((nr, batch, cw - 1, w), dtype)
    if cfg.vision is not None:
        nc = len(cfg.cross_attn_layers())
        cache["cross_k"] = jnp.zeros((nc, batch, cfg.vision.n_patches, hkv, hd), dtype)
        cache["cross_v"] = jnp.zeros((nc, batch, cfg.vision.n_patches, hkv, hd), dtype)
    if cfg.encoder is not None:
        cache["cross_k"] = jnp.zeros((cfg.n_layers, batch, cfg.encoder.n_frames, hkv, hd), dtype)
        cache["cross_v"] = jnp.zeros((cfg.n_layers, batch, cfg.encoder.n_frames, hkv, hd), dtype)
    return cache


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def prefill(cfg: ModelConfig, params, tokens: jax.Array, cache: Cache,
            vision_embeds: Optional[jax.Array] = None,
            frames: Optional[jax.Array] = None,
            attn_impl: str = "auto", n_valid=None) -> Tuple[jax.Array, Cache]:
    """Process a prompt chunk starting at cache['length'] (per sequence).
    Returns (last-position logits (B, Vp), updated cache).

    Attention within the chunk sees fresh activations (flash path); tokens
    also attend to previously cached context when cache['length'] > 0 by
    concatenating the cached prefix (engine chunked-prefill path).

    ``n_valid`` (static or traced scalar, bucketed-prefill contract,
    DESIGN.md §12): only the first n_valid of the s chunk positions are
    real. Pad positions are masked out of attention by position sentinels,
    made exact identity steps in the recurrences, and excluded from the
    length/logits bookkeeping — their (garbage) KV writes land in slots a
    later chunk overwrites or decode masks by length.
    """
    b, s = tokens.shape
    nv = s if n_valid is None else n_valid
    start = cache["length"]
    positions = start[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    x = T.embed(cfg, params, tokens)
    new_cache = dict(cache)
    kinds = cfg.layer_kinds()
    wins = T.window_schedule(cfg)

    if cfg.vision is not None and vision_embeds is not None:
        _fill_cross_cache(cfg, params["cross_blocks"], vision_embeds, new_cache)
    if cfg.encoder is not None:
        assert frames is not None
        mem = T.encode(cfg, params, frames, attn_impl)
        _fill_cross_cache(cfg, params["cross_blocks"], mem, new_cache)

    if cfg.attn_kind == "rwkv":
        x, new_cache = _rwkv_prefill(cfg, params, x, new_cache, n_valid)
    elif cfg.attn_kind == "hybrid_rglru":
        x, new_cache = _rglru_prefill(cfg, params, x, positions, new_cache,
                                      attn_impl, n_valid)
    else:
        # pads need no explicit masking here: their cache writes sit at
        # positions > every real query (causally excluded) and are
        # overwritten by the next chunk / masked by `length` at decode.
        x, new_cache = _attn_prefill(cfg, params, x, positions, new_cache,
                                     attn_impl, wins, kinds)

    new_cache["length"] = start + nv
    if n_valid is None:
        x_last = x[:, -1:, :]
    else:
        x_last = jax.lax.dynamic_slice_in_dim(x, nv - 1, 1, axis=1)
    logits = T.unembed(cfg, params, x_last)
    return logits[:, 0, :], new_cache


def _cache_kpos(cache_len_total: int, start: jax.Array, s: int) -> jax.Array:
    """Positions of cache slots: slot i holds token i; unwritten slots get a
    huge sentinel so masks exclude them."""
    idx = jnp.arange(cache_len_total, dtype=jnp.int32)[None, :]
    valid = idx < (start + s)[:, None]
    return jnp.where(valid, idx, GLOBAL_WINDOW + 1)


def _write_kv(cache_k, cache_v, li, k_new, v_new, start):
    b, s = k_new.shape[0], k_new.shape[1]
    bidx = jnp.arange(b)[:, None]
    widx = start[:, None] + jnp.arange(s)[None, :]
    return (cache_k.at[li, bidx, widx].set(k_new),
            cache_v.at[li, bidx, widx].set(v_new))


def _attn_prefill(cfg, params, x, positions, cache, attn_impl, wins, kinds):
    start = cache["length"]
    b, s, _ = x.shape
    has_prefix = cache["k"].shape[2] > 0
    is_vlm = cfg.vision is not None
    is_encdec = cfg.encoder is not None
    cross_layers = set(cfg.cross_attn_layers()) if is_vlm else set()

    smax = cache["k"].shape[2]
    # Engine chunked-prefill (small caches) attends jointly over the cache
    # after writing fresh k/v — exact continuation semantics. The large
    # single-shot path (dry-run 32k prefill, start==0) attends over the
    # fresh activations with the flash scan and writes the cache separately.
    joint_over_cache = smax <= 2048

    def run_block(i_attn, p, h, win, ck, cv):
        hh = L.apply_norm(h, p["ln1"], cfg.norm)
        q, k_new, v_new = L.attn_qkv(p["attn"], hh, cfg.n_heads, cfg.n_kv_heads,
                                     cfg.head_dim, positions, cfg.rope_theta, cfg.qk_norm)
        ck, cv = _write_kv(ck, cv, i_attn, k_new, v_new, start)
        if joint_over_cache:
            k_pos = _cache_kpos(smax, start, h.shape[1])
            mask = L.causal_mask(positions, k_pos)
            mask &= k_pos[:, None, :] > (positions[:, :, None] - win)
            o = L.attention(q, ck[i_attn], cv[i_attn], mask, cfg.attn_logit_softcap)
        else:
            o = T._self_attention(cfg, q, k_new, v_new, positions, positions,
                                  win, attn_impl, False)
        h = h + _post_attn(cfg, p, L.attn_out(p["attn"], o))
        hh = L.apply_norm(h, p["ln2"], cfg.norm)
        if "moe" in p:
            from repro.models import moe as M
            m = M.moe_apply(p["moe"], hh, cfg.moe, cfg.mlp_act, groups=T._moe_groups(hh))
        else:
            m = L.mlp_apply(p["mlp"], hh, cfg.mlp_act)
        if cfg.post_norms:
            m = L.apply_norm(m, p["ln2_post"], cfg.norm)
        return h + m, ck, cv

    ck, cv = cache["k"], cache["v"]
    i_attn = 0
    for i, kind in enumerate(kinds):
        p = jax.tree.map(lambda a: a[i], params["blocks"])
        x, ck, cv = run_block(i_attn, p, x, wins[i], ck, cv)
        if is_vlm and i in cross_layers:
            ci = sorted(cross_layers).index(i)
            pc = jax.tree.map(lambda a: a[ci], params["cross_blocks"])
            x = T.cross_block_apply(cfg, pc, x, cache["cross_k"][ci],
                                    cache["cross_v"][ci], gated=True)
        if is_encdec:
            pc = jax.tree.map(lambda a: a[i], params["cross_blocks"])
            x = T.cross_block_apply(cfg, pc, x, cache["cross_k"][i],
                                    cache["cross_v"][i], gated=False)
        i_attn += 1
    cache = dict(cache)
    cache["k"], cache["v"] = ck, cv
    return x, cache


def _post_attn(cfg, p, o):
    if cfg.post_norms:
        o = L.apply_norm(o, p["ln1_post"], cfg.norm)
    return o


def _rwkv_prefill(cfg, params, x, cache, n_valid=None):
    from repro.models.transformer import rwkv_block_apply

    def body(carry, xs):
        h = carry
        p, st, ltm, lcm = xs
        h, st, ltm, lcm = rwkv_block_apply(cfg, p, h, st, ltm, lcm, chunked=True,
                                           n_valid=n_valid)
        return h, (st, ltm, lcm)

    x, (st, ltm, lcm) = jax.lax.scan(body, x, (params["blocks"], cache["state"],
                                               cache["last_tm"], cache["last_cm"]))
    cache = dict(cache)
    cache["state"], cache["last_tm"], cache["last_cm"] = st, ltm, lcm
    return x, cache


def _rglru_prefill(cfg, params, x, positions, cache, attn_impl, n_valid=None):
    from repro.models.transformer import attn_block_apply, rglru_block_apply
    start = cache["length"]
    ck, cv = cache.get("k"), cache.get("v")
    hs, convs = cache["h"], cache["conv"]
    new_h, new_conv = [], []
    ri = ai = 0
    for kind in cfg.layer_kinds():
        if kind == "rglru":
            p = params["rglru_blocks"][ri]
            x, h_i, c_i = T.rglru_block_apply(cfg, p, x, hs[ri], convs[ri],
                                              n_valid=n_valid)
            new_h.append(h_i)
            new_conv.append(c_i)
            ri += 1
        else:
            p = params["attn_blocks"][ai]
            win = jnp.int32(cfg.window or GLOBAL_WINDOW)
            smax = ck.shape[2]
            hh = L.apply_norm(x, p["ln1"], cfg.norm)
            q, k_new, v_new = L.attn_qkv(p["attn"], hh, cfg.n_heads, cfg.n_kv_heads,
                                         cfg.head_dim, positions, cfg.rope_theta, cfg.qk_norm)
            ck, cv = _write_kv(ck, cv, ai, k_new, v_new, start)
            if smax <= 2048:  # joint continuation over cache (engine path)
                k_pos = _cache_kpos(smax, start, x.shape[1])
                mask = L.causal_mask(positions, k_pos)
                mask &= k_pos[:, None, :] > (positions[:, :, None] - win)
                o = L.attention(q, ck[ai], cv[ai], mask, cfg.attn_logit_softcap)
            else:
                o = T._self_attention(cfg, q, k_new, v_new, positions, positions,
                                      win, attn_impl, False)
            x = x + L.attn_out(p["attn"], o)
            hh = L.apply_norm(x, p["ln2"], cfg.norm)
            x = x + L.mlp_apply(p["mlp"], hh, cfg.mlp_act)
            ai += 1
    cache = dict(cache)
    cache["h"] = jnp.stack(new_h)
    cache["conv"] = jnp.stack(new_conv)
    if ck is not None:
        cache["k"], cache["v"] = ck, cv
    return x, cache


def _fill_cross_cache(cfg, cross_blocks, mem, cache):
    n = cache["cross_k"].shape[0]
    ks, vs = [], []
    for i in range(n):
        pa = jax.tree.map(lambda a: a[i], cross_blocks)["attn"]
        k, v = T.memory_kv(cfg, pa, mem)
        ks.append(k)
        vs.append(v)
    cache["cross_k"] = jnp.stack(ks)
    cache["cross_v"] = jnp.stack(vs)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def decode_step(cfg: ModelConfig, params, token: jax.Array, cache: Cache
                ) -> Tuple[jax.Array, Cache]:
    """One decode step for every family. token: (B,) int32.
    Returns (logits (B, Vp), updated cache)."""
    b = token.shape[0]
    lengths = cache["length"]
    positions = lengths[:, None]                                  # (B,1)
    x = T.embed(cfg, params, token[:, None])
    wins = T.window_schedule(cfg)
    kinds = cfg.layer_kinds()
    new_cache = dict(cache)

    if cfg.attn_kind == "rwkv":
        x, new_cache = _rwkv_decode(cfg, params, x, new_cache)
    elif cfg.attn_kind == "hybrid_rglru":
        x, new_cache = _rglru_decode(cfg, params, x, positions, new_cache)
    elif cfg.vision is not None:
        x, new_cache = _attn_decode(cfg, params, x, positions, new_cache,
                                    wins, vlm=True)
    elif cfg.encoder is not None:
        x, new_cache = _attn_decode(cfg, params, x, positions, new_cache,
                                    wins, encdec=True)
    else:
        x, new_cache = _attn_decode(cfg, params, x, positions, new_cache, wins)

    new_cache["length"] = lengths + 1
    logits = T.unembed(cfg, params, x)
    return logits[:, 0, :], new_cache


def _decode_attention(cfg, p, x, positions, k_cache, v_cache, win, lengths):
    """One self-attention block in decode mode (naive masked attention over
    the seq-sharded cache — flash-decode via GSPMD reductions).

    With perf_flags.windowed_decode and a static sliding window covering
    every attn layer (SWA / hybrid archs), only the trailing `window+1`
    cache positions are read — bytes ∝ window instead of context length.
    """
    from repro.models import perf_flags as PF
    b = x.shape[0]
    smax = k_cache.shape[1]
    h = L.apply_norm(x, p["ln1"], cfg.norm)
    q, k_new, v_new = L.attn_qkv(p["attn"], h, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.head_dim, positions, cfg.rope_theta, cfg.qk_norm)
    bidx = jnp.arange(b)
    static_win = cfg.window if cfg.attn_kind in ("swa", "hybrid_rglru") else None
    ring = (static_win is not None and smax <= ring_len(cfg)
            and cfg.window < 2 ** 20)
    if ring:
        # rotating buffer: slot j holds the newest token t ≡ j (mod ring).
        # No gathers: the whole (small) ring is attended, masks do the rest,
        # and the ring dim itself shards over the mesh.
        lm1 = lengths  # position of the incoming token
        k_cache = k_cache.at[bidx, lm1 % smax].set(k_new[:, 0])
        v_cache = v_cache.at[bidx, lm1 % smax].set(v_new[:, 0])
        j = jnp.arange(smax, dtype=jnp.int32)[None, :]
        delta = jnp.mod(lm1[:, None] - j, smax)            # ≥ 0
        t = lm1[:, None] - delta                           # token id per slot
        k_pos = jnp.where(t >= 0, t, GLOBAL_WINDOW + 1)
        mask = L.causal_mask(positions, k_pos)
        mask &= k_pos[:, None, :] > (positions[:, :, None] - win)
        o = L.attention(q, k_cache, v_cache, mask, cfg.attn_logit_softcap)
        o = L.attn_out(p["attn"], o)
        return o, k_cache, v_cache

    k_cache = k_cache.at[bidx, lengths].set(k_new[:, 0])
    v_cache = v_cache.at[bidx, lengths].set(v_new[:, 0])

    if (PF.get().windowed_decode and static_win is not None
            and static_win + 1 < smax):
        span = static_win + 1
        start = jnp.clip(lengths - static_win, 0, smax - span)
        cols = start[:, None] + jnp.arange(span)[None, :]          # (B, span)
        k_r = k_cache[bidx[:, None], cols]                         # (B, span, Hkv, hd)
        v_r = v_cache[bidx[:, None], cols]
        k_pos = jnp.where(cols <= lengths[:, None], cols, GLOBAL_WINDOW + 1)
    else:
        k_r, v_r = k_cache, v_cache
        k_pos = jnp.where(jnp.arange(smax)[None, :] <= lengths[:, None],
                          jnp.arange(smax, dtype=jnp.int32)[None, :],
                          GLOBAL_WINDOW + 1)
    mask = L.causal_mask(positions, k_pos)
    mask &= k_pos[:, None, :] > (positions[:, :, None] - win)
    o = L.attention(q, k_r, v_r, mask, cfg.attn_logit_softcap)
    o = L.attn_out(p["attn"], o)
    return o, k_cache, v_cache


def _attn_decode(cfg, params, x, positions, cache, wins, vlm=False, encdec=False):
    lengths = cache["length"]
    if vlm or encdec:
        # unrolled (cross blocks interleave); still cheap at Sq=1.
        return _attn_decode_unrolled(cfg, params, x, positions, cache, wins,
                                     vlm=vlm, encdec=encdec)

    def body(h, xs):
        p, kc, vc, w = xs
        o, kc, vc = _decode_attention(cfg, p, h, positions, kc, vc, w, lengths)
        h = h + _post_attn(cfg, p, o)
        hh = L.apply_norm(h, p["ln2"], cfg.norm)
        if "moe" in p:
            from repro.models import moe as M
            m = M.moe_apply(p["moe"], hh, cfg.moe, cfg.mlp_act, groups=1)
        else:
            m = L.mlp_apply(p["mlp"], hh, cfg.mlp_act)
        if cfg.post_norms:
            m = L.apply_norm(m, p["ln2_post"], cfg.norm)
        return h + m, (kc, vc)

    x, (ck, cv) = jax.lax.scan(body, x, (params["blocks"], cache["k"],
                                         cache["v"], wins))
    cache = dict(cache)
    cache["k"], cache["v"] = ck, cv
    return x, cache


def _attn_decode_unrolled(cfg, params, x, positions, cache, wins, vlm, encdec):
    lengths = cache["length"]
    ck, cv = cache["k"], cache["v"]
    cross_layers = sorted(cfg.cross_attn_layers()) if vlm else []
    for i, kind in enumerate(cfg.layer_kinds()):
        p = jax.tree.map(lambda a: a[i], params["blocks"])
        o, k_i, v_i = _decode_attention(cfg, p, x, positions, ck[i], cv[i],
                                        wins[i], lengths)
        ck, cv = ck.at[i].set(k_i), cv.at[i].set(v_i)
        x = x + _post_attn(cfg, p, o)
        h = L.apply_norm(x, p["ln2"], cfg.norm)
        if "moe" in p:
            from repro.models import moe as M
            m = M.moe_apply(p["moe"], h, cfg.moe, cfg.mlp_act, groups=1)
        else:
            m = L.mlp_apply(p["mlp"], h, cfg.mlp_act)
        if cfg.post_norms:
            m = L.apply_norm(m, p["ln2_post"], cfg.norm)
        x = x + m
        if vlm and i in cross_layers:
            ci = cross_layers.index(i)
            pc = jax.tree.map(lambda a: a[ci], params["cross_blocks"])
            x = T.cross_block_apply(cfg, pc, x, cache["cross_k"][ci],
                                    cache["cross_v"][ci], gated=True)
        if encdec:
            pc = jax.tree.map(lambda a: a[i], params["cross_blocks"])
            x = T.cross_block_apply(cfg, pc, x, cache["cross_k"][i],
                                    cache["cross_v"][i], gated=False)
    cache = dict(cache)
    cache["k"], cache["v"] = ck, cv
    return x, cache


def _rwkv_decode(cfg, params, x, cache):
    def body(h, xs):
        p, st, ltm, lcm = xs
        h, st, ltm, lcm = T.rwkv_block_apply(cfg, p, h, st, ltm, lcm, chunked=False)
        return h, (st, ltm, lcm)

    x, (st, ltm, lcm) = jax.lax.scan(body, x, (params["blocks"], cache["state"],
                                               cache["last_tm"], cache["last_cm"]))
    cache = dict(cache)
    cache["state"], cache["last_tm"], cache["last_cm"] = st, ltm, lcm
    return x, cache


def _rglru_decode(cfg, params, x, positions, cache):
    lengths = cache["length"]
    ck, cv = cache["k"], cache["v"]
    hs, convs = cache["h"], cache["conv"]
    new_h, new_conv = [], []
    ri = ai = 0
    for kind in cfg.layer_kinds():
        if kind == "rglru":
            p = params["rglru_blocks"][ri]
            x, h_i, c_i = T.rglru_block_apply(cfg, p, x, hs[ri], convs[ri], decode=True)
            new_h.append(h_i)
            new_conv.append(c_i)
            ri += 1
        else:
            p = params["attn_blocks"][ai]
            o, k_i, v_i = _decode_attention(cfg, p, x, positions, ck[ai], cv[ai],
                                            jnp.int32(cfg.window or GLOBAL_WINDOW), lengths)
            ck, cv = ck.at[ai].set(k_i), cv.at[ai].set(v_i)
            x = x + o
            h = L.apply_norm(x, p["ln2"], cfg.norm)
            x = x + L.mlp_apply(p["mlp"], h, cfg.mlp_act)
            ai += 1
    cache = dict(cache)
    cache["h"], cache["conv"] = jnp.stack(new_h), jnp.stack(new_conv)
    cache["k"], cache["v"] = ck, cv
    return x, cache
