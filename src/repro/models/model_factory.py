"""Bundle a ModelConfig into callables the engine / launcher / tests use."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, get_config, smoke_config
from repro.models import serving as S
from repro.models import transformer as T


@dataclass(frozen=True)
class ModelBundle:
    cfg: ModelConfig
    init_params: Callable[..., Dict[str, Any]]
    forward: Callable[..., jax.Array]          # teacher-forced logits
    init_cache: Callable[..., S.Cache]
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]

    def loss_fn(self, params, tokens, targets, mask, **extra):
        """Mean next-token cross-entropy over `mask`-ed positions."""
        logits = self.forward(self.cfg, params, tokens, **extra)
        return cross_entropy(logits, targets, mask, self.cfg.vocab_size)

    def extra_inputs(self, batch: int, dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
        """Modality-stub inputs (zeros) for vlm/audio families."""
        cfg = self.cfg
        out: Dict[str, jax.Array] = {}
        if cfg.vision is not None:
            out["vision_embeds"] = jnp.zeros((batch, cfg.vision.n_patches, cfg.d_model), dtype)
        if cfg.encoder is not None:
            out["frames"] = jnp.zeros((batch, cfg.encoder.n_frames, cfg.d_model), dtype)
        return out


def cross_entropy(logits: jax.Array, targets: jax.Array, mask: jax.Array,
                  vocab_size: int) -> jax.Array:
    """logits: (B,S,Vp) — pad-vocab entries are excluded by masking."""
    vp = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if vp > vocab_size:
        pad = jnp.arange(vp) >= vocab_size
        logits = jnp.where(pad[None, None, :], -1e30, logits)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


def get_model(name_or_cfg, smoke: bool = False) -> ModelBundle:
    cfg = name_or_cfg if isinstance(name_or_cfg, ModelConfig) else get_config(name_or_cfg)
    if smoke:
        cfg = smoke_config(cfg)
    return ModelBundle(
        cfg=cfg,
        init_params=lambda key, dtype=jnp.bfloat16: T.init_params(cfg, key, dtype),
        forward=T.forward,
        init_cache=lambda batch, max_len, dtype=jnp.bfloat16: S.init_cache(cfg, batch, max_len, dtype),
        prefill=S.prefill,
        decode_step=S.decode_step,
    )
