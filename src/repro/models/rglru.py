"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    a_t = exp(c * softplus(Λ) * (-r_t))   = a^{c·r_t},  a = sigmoid(Λ)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

The sequence form uses ``jax.lax.associative_scan`` — log-depth, fully
unrolled HLO (no while loop), so cost probes are exact and GSPMD partitions
it cleanly. Decode is the single-step recurrence.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

_C = 8.0


def init_rglru_block(key: jax.Array, d: int, width: int, conv_width: int,
                     dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    sw = 1.0 / math.sqrt(width)
    return {
        "w_in": (jax.random.normal(ks[0], (d, width)) * s).astype(dtype),
        "w_gate_in": (jax.random.normal(ks[1], (d, width)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[2], (conv_width, width)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((width,), dtype),
        "wa": (jax.random.normal(ks[3], (width, width)) * sw).astype(dtype),
        "wx": (jax.random.normal(ks[4], (width, width)) * sw).astype(dtype),
        "lambda_p": jnp.full((width,), 2.0, jnp.float32),  # sigmoid ≈ .88 decay
        "w_out": (jax.random.normal(ks[5], (width, d)) * sw).astype(dtype),
    }


def _rglru_coeffs(p: dict, u: jax.Array):
    """u: (B,T,W) post-conv activations -> (a, b) with h_t = a h + b."""
    rg = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", u, p["wa"]).astype(jnp.float32))
    ig = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", u, p["wx"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lambda_p"]) * rg          # log a_t ≤ 0
    a = jnp.exp(log_a)
    gated = ig * u.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated
    return a, b


def rglru_scan(p: dict, u: jax.Array, h0: jax.Array, n_valid=None):
    """Associative-scan linear recurrence. u: (B,T,W); h0: (B,W).

    Positions >= ``n_valid`` (static or traced) are padding: their steps
    become exact identities (a -> 1, b -> 0), so every h_t from n_valid-1
    onward — including the returned final state — equals h_{n_valid-1}."""
    a, b = _rglru_coeffs(p, u)
    if n_valid is not None:
        valid = (jnp.arange(u.shape[1]) < n_valid)[None, :, None]
        a = jnp.where(valid, a, 1.0)
        b = jnp.where(valid, b, 0.0)
    # fold h0 into the first step: h_1 = a_1 h0 + b_1
    b = b.at[:, 0, :].add(a[:, 0, :] * h0.astype(jnp.float32))

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    av, bv = jax.lax.associative_scan(combine, (a, b), axis=1)
    return bv, bv[:, -1, :]                                     # h_t for all t; final state


def rglru_step(p: dict, u: jax.Array, h: jax.Array):
    """Single decode step. u: (B,1,W); h: (B,W)."""
    a, b = _rglru_coeffs(p, u)
    h = a[:, 0] * h.astype(jnp.float32) + b[:, 0]
    return h, h


def conv1d_apply(p: dict, u: jax.Array, conv_state: jax.Array, n_valid=None):
    """Depthwise causal conv. u: (B,T,W); conv_state: (B,cw-1,W) trailing
    inputs from the previous call. Returns (y, new_conv_state). With
    ``n_valid`` set, new_conv_state carries the cw-1 inputs trailing the
    last REAL position (pads only corrupt pad outputs, which are unused)."""
    cw = p["conv_w"].shape[0]
    full = jnp.concatenate([conv_state.astype(u.dtype), u], axis=1)   # (B,cw-1+T,W)
    t = u.shape[1]
    y = jnp.zeros_like(u, dtype=jnp.float32)
    for i in range(cw):  # static tiny loop (cw = 4)
        y = y + full[:, i:i + t, :].astype(jnp.float32) * p["conv_w"][i].astype(jnp.float32)
    y = y + p["conv_b"].astype(jnp.float32)
    if cw <= 1:
        new_state = jnp.zeros_like(conv_state)
    elif n_valid is None:
        new_state = full[:, -(cw - 1):, :]
    else:
        # token j lives at full[:, (cw-1)+j] ⇒ the run ending at n_valid-1
        # starts at index n_valid
        new_state = jax.lax.dynamic_slice_in_dim(full, n_valid, cw - 1, axis=1)
    return y.astype(u.dtype), new_state


def rglru_block_apply(p: dict, x: jax.Array, h0: jax.Array, conv_state: jax.Array,
                      decode: bool = False, n_valid=None):
    """Full Griffin recurrent block: (gelu gate) ⊙ (conv → RG-LRU) → out proj.
    x: (B,T,D). Returns (y, new_h, new_conv_state)."""
    gate = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, p["w_gate_in"]), approximate=True)
    u = jnp.einsum("btd,dw->btw", x, p["w_in"])
    u, conv_state = conv1d_apply(p, u, conv_state, n_valid=n_valid)
    if decode:
        hseq, h = rglru_step(p, u, h0)
        hseq = hseq[:, None, :]
    else:
        hseq, h = rglru_scan(p, u, h0, n_valid=n_valid)
    y = (hseq.astype(x.dtype) * gate)
    y = jnp.einsum("btw,wd->btd", y, p["w_out"])
    return y, h, conv_state
