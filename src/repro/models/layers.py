"""Core neural-net building blocks, pure-functional JAX.

Everything here is written so that GSPMD can partition it on the production
mesh: plain einsum/where math, fp32 softmax/norm accumulation, bf16 weights.
The Pallas kernels in ``repro.kernels`` implement the serving hot paths of
the same math and are validated against these references.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    # gemma-style (1 + w) keeps zero-init identity; generic enough for all archs
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def apply_norm(x: jax.Array, p: dict, kind: str) -> jax.Array:
    if kind == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


def init_norm(d: int, kind: str, dtype=jnp.float32) -> dict:
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return {"scale": jnp.zeros((d,), dtype)}  # rms uses (1 + w)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = rope_freqs(hd, theta)                                # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs       # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                             # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:2 * half].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    if hd % 2:  # odd head_dim (h2o-danube head_dim=120 is even; safety anyway)
        out = jnp.concatenate([out, x[..., 2 * half:].astype(jnp.float32)], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (reference jnp paths used by the compiled distributed steps)
# ---------------------------------------------------------------------------


def _softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(B, S, Hkv, hd) -> (B, S, Hkv*n_rep, hd)."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(b, s, h * n_rep, d)


def causal_mask(q_pos: jax.Array, k_pos: jax.Array, window: Optional[int] = None) -> jax.Array:
    """Boolean (..., Sq, Sk): True = attend. Sliding window keeps
    k_pos in (q_pos - window, q_pos]."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        m &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return m


def attention(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array,
              softcap: Optional[float] = None, scale: Optional[float] = None) -> jax.Array:
    """Naive (materialized-scores) attention, grouped-query form: KV heads
    are never repeated/materialized (critical for the seq-sharded decode
    cache — a broadcast here forces GSPMD into full rematerialization).
    q: (B,Sq,H,hd); k,v: (B,Sk,Hkv,hd)."""
    b, sq, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(b, sq, hkv, g, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    scores = _softcap(scores, softcap)
    if mask is not None:  # None = attend to everything (cross attention)
        m = mask[:, None, None, :, :] if mask.ndim == 3 else mask
        scores = jnp.where(m, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return o.reshape(b, sq, h, hd)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    q_positions: jax.Array, k_positions: jax.Array,
                    window: Optional[int] = None, softcap: Optional[float] = None,
                    chunk: int = 1024, unroll: bool = False,
                    causal: bool = True) -> jax.Array:
    """Memory-efficient attention: scans over key/value chunks with a running
    (max, sum, acc) triple so the (Sq, Sk) score matrix is never materialized.
    This is the compiled-artifact path for 32k/500k contexts. ``unroll=True``
    removes the while-loop so cost_analysis counts every chunk (probe mode).

    q: (B,Sq,H,hd); k,v: (B,Sk,Hkv,hd); positions give absolute token indices.
    """
    b, sq, h, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    n_rep = h // hkv
    scale = 1.0 / math.sqrt(hd)
    n_chunks = max(1, (sk + chunk - 1) // chunk)
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_positions = jnp.pad(k_positions, ((0, 0), (0, pad)), constant_values=2 ** 30)
    kc = k.reshape(b, n_chunks, chunk, hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, hkv, hd).transpose(1, 0, 2, 3, 4)
    pc = k_positions.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    qf = q.reshape(b, sq, hkv, n_rep, hd).astype(jnp.float32)  # grouped-query

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        kb, vb, pb = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kb.astype(jnp.float32)) * scale
        s = _softcap(s, softcap)
        if causal:
            msk = causal_mask(q_positions, pb, window)           # (B, Sq, C)
        else:
            msk = (pb < 2 ** 30)[:, None, :] & jnp.ones((b, sq, 1), bool)
        s = jnp.where(msk[:, None, None, :, :], s, -1e30)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_cur[..., None])
        corr = jnp.exp(m_prev - m_cur)
        l_cur = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32))
        return (m_cur, l_cur, acc), None

    init = (jnp.full((b, hkv, n_rep, sq), -jnp.inf, jnp.float32),
            jnp.zeros((b, hkv, n_rep, sq), jnp.float32),
            jnp.zeros((b, hkv, n_rep, sq, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, (kc, vc, pc),
                                  unroll=n_chunks if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]           # (B,Hkv,G,Sq,hd)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def banded_swa_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                         window: int, softcap: Optional[float] = None,
                         q_block: int = 1024) -> jax.Array:
    """Sliding-window self-attention over a gathered diagonal band: query
    block i attends keys [i·Q − window, i·Q + Q). FLOPs and bytes scale with
    S·(window+Q) instead of S² (the full-causal chunk scan computes every
    masked chunk). Exact w.r.t. masked attention (validated in tests).

    q: (B,S,H,hd); k,v: (B,S,Hkv,hd); from-scratch prefill (positions =
    arange(S)). S % q_block == 0.
    """
    b, s, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qb = min(q_block, s)
    assert s % qb == 0, (s, qb)
    nb = s // qb
    band = window + qb
    scale = 1.0 / math.sqrt(hd)

    starts = jnp.arange(nb) * qb - window                          # (nb,)
    idx = starts[:, None] + jnp.arange(band)[None, :]              # (nb, band)
    valid_idx = idx >= 0
    idx_c = jnp.clip(idx, 0, s - 1)
    kb = k[:, idx_c]                                               # (B,nb,band,Hkv,hd)
    vb = v[:, idx_c]
    qg = q.reshape(b, nb, qb, hkv, g, hd)

    sc = jnp.einsum("bnqhgd,bnkhd->bnhgqk", qg.astype(jnp.float32),
                    kb.astype(jnp.float32)) * scale
    sc = _softcap(sc, softcap)
    qpos = (jnp.arange(nb) * qb)[:, None] + jnp.arange(qb)[None, :]  # (nb, qb)
    mask = idx[:, None, :] <= qpos[:, :, None]                     # causal
    mask &= idx[:, None, :] > (qpos[:, :, None] - window)          # window
    mask &= valid_idx[:, None, :]
    sc = jnp.where(mask[None, :, None, None], sc, -1e30)
    pr = jax.nn.softmax(sc, axis=-1).astype(q.dtype)
    o = jnp.einsum("bnhgqk,bnkhd->bnqhgd", pr, vb)
    return o.reshape(b, s, h, hd)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_apply(p: dict, x: jax.Array, act: str) -> jax.Array:
    if act in ("swiglu", "geglu"):
        gate = jnp.einsum("...d,df->...f", x, p["w_gate"])
        up = jnp.einsum("...d,df->...f", x, p["w_up"])
        g = jax.nn.silu(gate) if act == "swiglu" else jax.nn.gelu(gate, approximate=True)
        h = g * up
    elif act == "sqrelu":
        h = jnp.square(jax.nn.relu(jnp.einsum("...d,df->...f", x, p["w_up"])))
    else:
        raise ValueError(act)
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


def init_mlp(key: jax.Array, d: int, f: int, act: str, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {"w_up": (jax.random.normal(k2, (d, f)) * s_in).astype(dtype),
         "w_down": (jax.random.normal(k3, (f, d)) * s_out).astype(dtype)}
    if act in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(k1, (d, f)) * s_in).astype(dtype)
    return p


# ---------------------------------------------------------------------------
# Attention block params
# ---------------------------------------------------------------------------


def init_attn(key: jax.Array, d: int, n_heads: int, n_kv: int, hd: int,
              qk_norm: bool = False, dtype=jnp.bfloat16) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    so = 1.0 / math.sqrt(n_heads * hd)
    p = {"wq": (jax.random.normal(kq, (d, n_heads * hd)) * s).astype(dtype),
         "wk": (jax.random.normal(kk, (d, n_kv * hd)) * s).astype(dtype),
         "wv": (jax.random.normal(kv, (d, n_kv * hd)) * s).astype(dtype),
         "wo": (jax.random.normal(ko, (n_heads * hd, d)) * so).astype(dtype)}
    if qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def attn_qkv(p: dict, x: jax.Array, n_heads: int, n_kv: int, hd: int,
             positions: jax.Array, theta: float, qk_norm: bool = False,
             rope: bool = True):
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(b, s, n_heads, hd)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(b, s, n_kv, hd)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(b, s, n_kv, hd)
    if qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if rope:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    return q, k, v


def attn_out(p: dict, o: jax.Array) -> jax.Array:
    b, s, h, hd = o.shape
    return jnp.einsum("bsh,hd->bsd", o.reshape(b, s, h * hd), p["wo"])
