"""gemma2-9b — dense; local(4096)+global alternating attention, logit softcaps.
[arXiv:2408.00118; hf]"""
from repro.configs.base import ModelConfig, register

GEMMA2_9B = register(ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    attn_kind="local_global",
    window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    mlp_act="geglu",
    rope_theta=10000.0,
    post_norms=True,
    embed_scale=True,
    tie_embeddings=True,
    source="[arXiv:2408.00118; hf]",
))
