"""seamless-m4t-large-v2 — enc-dec multimodal (audio). The speech frontend
is a stub: input_specs provides precomputed frame embeddings.
[arXiv:2308.11596; hf]"""
from repro.configs.base import ModelConfig, EncoderConfig, register

SEAMLESS_M4T_LARGE_V2 = register(ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,           # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    attn_kind="global",
    mlp_act="sqrelu",      # relu-family FFN (conformer-style tower simplified)
    norm="layernorm",
    encoder=EncoderConfig(n_layers=24, n_frames=4096),
    source="[arXiv:2308.11596; hf]",
))
