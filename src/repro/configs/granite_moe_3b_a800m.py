"""granite-moe-3b-a800m — MoE: 40 experts, top-8, d_expert=512.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.configs.base import ModelConfig, MoEConfig, register

GRANITE_MOE_3B_A800M = register(ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,              # per-expert hidden
    vocab_size=49155,
    attn_kind="global",
    mlp_act="swiglu",
    rope_theta=10000.0,
    tie_embeddings=True,
    moe=MoEConfig(n_experts=40, top_k=8, d_expert=512),
    source="[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]",
))
