"""mixtral-8x7b — MoE: 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""
from repro.configs.base import ModelConfig, MoEConfig, register

MIXTRAL_8X7B = register(ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,            # per-expert hidden
    vocab_size=32000,
    attn_kind="swa",
    window=4096,
    mlp_act="swiglu",
    rope_theta=1000000.0,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=14336),
    source="[arXiv:2401.04088; hf]",
))
