from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, EncoderConfig, VisionConfig, RWKVConfig, RGLRUConfig,
    ShapeConfig, SHAPES, shape_applicable, get_config, list_configs, register,
    smoke_config,
)
