"""qwen3-8b — dense; GQA with qk-norm. [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ModelConfig, register

QWEN3_8B = register(ModelConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    attn_kind="global",
    qk_norm=True,
    mlp_act="swiglu",
    rope_theta=1000000.0,
    source="[hf:Qwen/Qwen3-8B; hf]",
))
