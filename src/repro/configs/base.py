"""Model / run configuration system.

Every assigned architecture is expressed as a ``ModelConfig``. Configs are
plain frozen dataclasses so they can be hashed into jit static args and
round-tripped through the launcher CLI.

The same config object drives:
  * parameter init + forward/train/prefill/decode (src/repro/models)
  * sharding rules (which dims are TP-shardable on the 16-way model axis)
  * the dry-run input_specs (src/repro/launch/dryrun.py)
  * the reduced "smoke" variant used by CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int          # per-expert FFN hidden size
    router_jitter: float = 0.0
    # Capacity factor used when dispatching with fixed-capacity all_to_all.
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder tower for enc-dec models (seamless-m4t). The modality
    frontend (speech feature extractor) is a stub: input_specs provides
    precomputed frame embeddings of shape (batch, n_frames, d_model)."""
    n_layers: int
    n_frames: int          # default encoder sequence length (precomputed frames)


@dataclass(frozen=True)
class VisionConfig:
    """Cross-attention vision adapter for VLMs (llama-3.2-vision). The
    vision tower is a stub: input_specs provides precomputed patch
    embeddings (batch, n_patches, d_model)."""
    cross_attn_every: int  # a cross-attn layer is inserted after every N self-attn layers
    n_patches: int


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    # interval (tokens) at which the engine checkpoints recurrent state so
    # prefix-cache hits can resume from the nearest boundary (DESIGN.md §4)
    state_ckpt_interval: int = 256


@dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int
    # block pattern: this many recurrent blocks per attention block
    recurrent_per_attn: int = 2
    conv1d_width: int = 4
    state_ckpt_interval: int = 256


# ---------------------------------------------------------------------------
# Main config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention flavour ---
    attn_kind: str = "global"       # global | swa | local_global | hybrid_rglru | rwkv
    window: Optional[int] = None    # sliding-window size when applicable
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    qk_norm: bool = False
    rope_theta: float = 10000.0

    # --- MLP flavour ---
    mlp_act: str = "swiglu"         # swiglu | geglu | sqrelu

    norm: str = "rmsnorm"           # rmsnorm | layernorm
    post_norms: bool = False        # gemma2-style post-attn/post-ffw norms
    embed_scale: bool = False       # gemma-style sqrt(d_model) embedding scale
    tie_embeddings: bool = False

    moe: Optional[MoEConfig] = None
    encoder: Optional[EncoderConfig] = None
    vision: Optional[VisionConfig] = None
    rwkv: Optional[RWKVConfig] = None
    rglru: Optional[RGLRUConfig] = None

    # citation per assignment: [source; verification tier]
    source: str = ""

    # ---------------- derived ----------------
    @property
    def attn_free(self) -> bool:
        return self.attn_kind == "rwkv"

    @property
    def subquadratic(self) -> bool:
        """True when a 500k-token decode context has bounded (or
        mesh-shardable-bounded) attention state: SSM / hybrid / SWA /
        alternating local-global."""
        return self.attn_kind in ("rwkv", "hybrid_rglru", "swa", "local_global")

    @property
    def is_encdec(self) -> bool:
        return self.encoder is not None

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the vocab dim is cleanly
        TP-shardable on a 16-way model axis (pad logits are masked)."""
        return ((self.vocab_size + 255) // 256) * 256

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind, length n_layers (decoder tower)."""
        kinds = []
        for i in range(self.n_layers):
            if self.attn_kind == "rwkv":
                kinds.append("rwkv")
            elif self.attn_kind == "hybrid_rglru":
                assert self.rglru is not None
                period = self.rglru.recurrent_per_attn + 1
                kinds.append("attn_local" if (i % period == self.rglru.recurrent_per_attn) else "rglru")
            elif self.attn_kind == "local_global":
                kinds.append("attn_local" if i % 2 == 0 else "attn_global")
            elif self.attn_kind == "swa":
                kinds.append("attn_local")
            else:
                kinds.append("attn_global")
        return tuple(kinds)

    def cross_attn_layers(self) -> Tuple[int, ...]:
        if self.vision is None:
            return ()
        k = self.vision.cross_attn_every
        return tuple(i for i in range(self.n_layers) if (i + 1) % k == 0)

    def param_count(self) -> int:
        """Approximate parameter count N (used for MODEL_FLOPS = 6·N·D)."""
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        qkv = d * self.n_heads * self.head_dim + 2 * d * self.n_kv_heads * self.head_dim
        o = self.n_heads * self.head_dim * d
        n = 0
        for kind in self.layer_kinds():
            if kind == "rwkv":
                # time-mix (r,k,v,g,o + decay/aaa) + channel-mix
                n += 6 * d * d + 2 * d * f + d * f  # rwkv channel mix is k,v,r
            elif kind == "rglru":
                assert self.rglru is not None
                w = self.rglru.lru_width
                n += 2 * d * w + w * d + 2 * w * self.rglru.conv1d_width
                n += self._mlp_params(d, f)
            else:
                n += qkv + o + self._mlp_params(d, f)
        if self.vision is not None:
            for _ in self.cross_attn_layers():
                n += qkv + o
        if self.encoder is not None:
            enc_layer = qkv + o + self._mlp_params(d, f)
            n += self.encoder.n_layers * enc_layer
            # decoder cross-attention
            n += self.n_layers * (qkv + o)
        n += v * d  # embeddings
        if not self.tie_embeddings:
            n += v * d
        return n

    def _mlp_params(self, d: int, f: int) -> int:
        if self.moe is not None:
            e = self.moe
            per = (3 if self.mlp_act in ("swiglu", "geglu") else 2) * d * e.d_expert
            return e.n_experts * per + d * e.n_experts  # + router
        mult = 3 if self.mlp_act in ("swiglu", "geglu") else 2
        return mult * d * f

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        per = (3 if self.mlp_act in ("swiglu", "geglu") else 2) * self.d_model * e.d_expert
        dead = (e.n_experts - e.top_k) * per * self.n_layers
        return self.param_count() - dead

    # ---------------- TP shardability (16-way model axis) ----------------
    def tp_heads_ok(self, tp: int = 16) -> bool:
        return self.n_heads % tp == 0

    def tp_ff_ok(self, tp: int = 16) -> bool:
        f = self.moe.d_expert if self.moe is not None else self.d_ff
        return f % tp == 0


# ---------------------------------------------------------------------------
# Input shapes (assigned): every LM-family arch is paired with these four.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell runs, and why not when skipped."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: 500k decode context skipped per assignment"
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    # import all per-arch modules exactly once
    if _REGISTRY:
        return
    from repro.configs import (  # noqa: F401
        gemma2_9b, nemotron_4_15b, h2o_danube_3_4b, qwen3_8b, rwkv6_1_6b,
        llama_3_2_vision_11b, granite_moe_3b_a800m, mixtral_8x7b,
        seamless_m4t_large_v2, recurrentgemma_2b,
    )


# ---------------------------------------------------------------------------
# Reduced smoke variant — same family/block pattern, tiny dims.
# ---------------------------------------------------------------------------


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Shrink a full config to a CPU-runnable variant of the same family."""
    changes: dict = dict(
        name=cfg.name + "-smoke",
        n_layers=min(cfg.n_layers, 4),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2),
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        window=16 if cfg.window else None,
    )
    if cfg.moe is not None:
        # capacity_factor high enough to be drop-free: chunked prefill /
        # decode / teacher-forced paths then agree bit-for-bit.
        changes["moe"] = MoEConfig(n_experts=4, top_k=min(cfg.moe.top_k, 2),
                                   d_expert=32, capacity_factor=100.0)
    if cfg.encoder is not None:
        changes["encoder"] = EncoderConfig(n_layers=2, n_frames=24)
    if cfg.vision is not None:
        # n_layers must stay divisible by cross_attn_every for the group scan
        changes["vision"] = VisionConfig(cross_attn_every=2, n_patches=16)
    if cfg.rwkv is not None:
        changes["rwkv"] = RWKVConfig(head_dim=16, state_ckpt_interval=8)
        changes["n_kv_heads"] = 4
    if cfg.rglru is not None:
        changes["rglru"] = RGLRUConfig(lru_width=64, recurrent_per_attn=cfg.rglru.recurrent_per_attn,
                                       conv1d_width=4, state_ckpt_interval=8)
        changes["n_layers"] = min(cfg.n_layers, 6)
        changes["n_kv_heads"] = 1
    return dataclasses.replace(cfg, **changes)
