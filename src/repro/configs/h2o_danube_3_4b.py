"""h2o-danube-3-4b — dense; llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; unverified]"""
from repro.configs.base import ModelConfig, register

H2O_DANUBE_3_4B = register(ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32000,
    attn_kind="swa",
    window=4096,
    mlp_act="swiglu",
    rope_theta=10000.0,
    source="[arXiv:2401.16818; unverified]",
))
