"""rwkv6-1.6b (Finch) — attention-free SSM with data-dependent decay.
[arXiv:2404.05892; unverified]"""
from repro.configs.base import ModelConfig, RWKVConfig, register

RWKV6_1_6B = register(ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,            # 2048 / head_dim 64
    n_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    attn_kind="rwkv",
    mlp_act="sqrelu",      # rwkv channel-mix uses squared relu
    norm="layernorm",
    rwkv=RWKVConfig(head_dim=64),
    source="[arXiv:2404.05892; unverified]",
))
