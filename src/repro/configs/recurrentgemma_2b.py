"""recurrentgemma-2b (Griffin) — hybrid: RG-LRU recurrent blocks + local
attention in a 2:1 pattern. [arXiv:2402.19427; hf]"""
from repro.configs.base import ModelConfig, RGLRUConfig, register

RECURRENTGEMMA_2B = register(ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,          # MQA on the local-attention blocks
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    attn_kind="hybrid_rglru",
    window=2048,
    mlp_act="geglu",
    rope_theta=10000.0,
    embed_scale=True,
    tie_embeddings=True,
    rglru=RGLRUConfig(lru_width=2560, recurrent_per_attn=2, conv1d_width=4),
    source="[arXiv:2402.19427; hf]",
))
