"""nemotron-4-15b — dense; GQA, squared-ReLU MLP. [arXiv:2402.16819; unverified]"""
from repro.configs.base import ModelConfig, register

NEMOTRON_4_15B = register(ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    attn_kind="global",
    mlp_act="sqrelu",
    norm="layernorm",
    rope_theta=10000.0,
    source="[arXiv:2402.16819; unverified]",
))
