"""llama-3.2-vision-11b — VLM; cross-attn image layers every 5th layer.
Vision tower is a stub: input_specs provides precomputed patch embeddings.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.configs.base import ModelConfig, VisionConfig, register

LLAMA_3_2_VISION_11B = register(ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    attn_kind="global",
    mlp_act="swiglu",
    rope_theta=500000.0,
    vision=VisionConfig(cross_attn_every=5, n_patches=1601),
    source="[hf:meta-llama/Llama-3.2-11B-Vision; unverified]",
))
