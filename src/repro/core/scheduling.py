"""Distributed scheduling policies (§5, Algorithm 1).

``dist_sched(req, tes)`` = PD_aware → (locality_aware | load_aware):
  1. PD-aware: pick the TE *type* (disaggregated pair vs colocated) from
     the combined heatmap + the decode-length predictor (§5.3);
  2. if the surviving group is load-balanced, prefer the TE with the
     longest prefix match in the global prompt tree (§5.2);
  3. otherwise pick the least-loaded TE.

TEs are described by ``TEHandle``s — the JE-side view (type, load, local
prompt-tree index shared with the global tree).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.heatmap import lookup
from repro.core.predictor import DecodeLengthPredictor
from repro.engine.radix_tree import RadixTree


@dataclass
class TEHandle:
    te_id: str
    te_type: str                        # "colocated" | "pd_pair"
    load: float = 0.0                   # outstanding work (tokens)
    n_running: int = 0
    engine: object = None               # live FlowServe (or sim TE)
    prompt_tree: RadixTree = field(default_factory=RadixTree)

    def record_prompt(self, tokens) -> None:
        self.prompt_tree.insert(tuple(tokens), self.te_id)


@dataclass
class SchedRequest:
    tokens: Sequence[int]
    predicted_decode: int = 128


class GlobalPromptTree:
    """JE-side: one tree per TE group; payloads are TE ids (§5.2)."""

    def __init__(self):
        self.tree = RadixTree()

    def record(self, tokens, te_id: str) -> None:
        self.tree.insert(tuple(tokens), te_id)

    def best_te(self, tokens, candidates: List[TEHandle]) -> Tuple[Optional[str], int]:
        """TE holding the longest matching prefix among candidates."""
        cand_ids = {t.te_id for t in candidates}
        best_id, best_len = None, 0
        matched, path = self.tree.match_prefix(tuple(tokens))
        # walk the matched path from deepest to shallowest; payload = te_id
        run = 0
        consumed = 0
        for node in path:
            consumed += len(node.key)
            payload = node.payload or self.tree.any_payload(node)
            if payload in cand_ids and min(consumed, matched) > best_len:
                best_id, best_len = payload, min(consumed, matched)
        return best_id, best_len


@dataclass
class DistSchedConfig:
    load_balance_threshold: float = 0.30   # max relative load spread
    min_prefix_tokens: int = 8             # ignore tiny prefix matches


class DistributedScheduler:
    """Runs inside a model-serving JE (one instance per TE group)."""

    def __init__(self, tes: List[TEHandle], combined_heatmap: np.ndarray,
                 prefill_lens, decode_ratios,
                 predictor: Optional[DecodeLengthPredictor] = None,
                 cfg: DistSchedConfig = DistSchedConfig()):
        self.tes = {t.te_id: t for t in tes}
        self.heatmap = combined_heatmap
        self.prefill_lens = prefill_lens
        self.decode_ratios = decode_ratios
        self.predictor = predictor
        self.cfg = cfg
        self.global_tree = GlobalPromptTree()
        self.decisions = {"pd_disagg": 0, "pd_colo": 0, "locality": 0, "load": 0}

    # ------------------------------------------------------ Algorithm 1
    def dist_sched(self, req: SchedRequest) -> TEHandle:
        tes = list(self.tes.values())
        tes = self.pd_aware(req, tes)
        if self._is_load_balanced(tes):
            chosen = self.locality_aware(req, tes)
        else:
            chosen = self.load_aware(req, tes)
        return chosen

    def pd_aware(self, req: SchedRequest, tes: List[TEHandle]) -> List[TEHandle]:
        p_len = len(req.tokens)
        d_len = req.predicted_decode
        if self.predictor is not None:
            d_len = self.predictor.predict_tokens(req.tokens)
        val = lookup(self.heatmap, self.prefill_lens, self.decode_ratios,
                     p_len, d_len)
        want = "pd_pair" if val > 0 else "colocated"
        sub = [t for t in tes if t.te_type == want]
        if not sub:                      # group has only one type
            return tes
        self.decisions["pd_disagg" if want == "pd_pair" else "pd_colo"] += 1
        return sub

    def locality_aware(self, req: SchedRequest, tes: List[TEHandle]) -> TEHandle:
        te_id, n = self.global_tree.best_te(req.tokens, tes)
        if te_id is not None and n >= self.cfg.min_prefix_tokens:
            self.decisions["locality"] += 1
            return self.tes[te_id]
        return self.load_aware(req, tes, count=False)

    def load_aware(self, req: SchedRequest, tes: List[TEHandle],
                   count: bool = True) -> TEHandle:
        if count:
            self.decisions["load"] += 1
        return min(tes, key=lambda t: t.load)

    # ------------------------------------------------------ bookkeeping
    def _is_load_balanced(self, tes: List[TEHandle]) -> bool:
        loads = [t.load for t in tes]
        if not loads or max(loads) <= 0:
            return True
        spread = (max(loads) - min(loads)) / max(max(loads), 1e-9)
        return spread <= self.cfg.load_balance_threshold

    def commit(self, req: SchedRequest, te: TEHandle) -> None:
        """Record placement: load + prompt-tree bookkeeping."""
        te.load += len(req.tokens) + req.predicted_decode
        te.n_running += 1
        self.global_tree.record(req.tokens, te.te_id)
        te.record_prompt(req.tokens)

    def complete(self, req: SchedRequest, te: TEHandle) -> None:
        te.load = max(0.0, te.load - (len(req.tokens) + req.predicted_decode))
        te.n_running = max(0, te.n_running - 1)


def round_robin_scheduler(tes: List[TEHandle]):
    """Baseline RR policy used in Figure 7's comparison."""
    state = {"i": 0}

    def pick(req: SchedRequest) -> TEHandle:
        te = tes[state["i"] % len(tes)]
        state["i"] += 1
        return te

    return pick
