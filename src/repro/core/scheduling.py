"""Distributed scheduling policies (§5, Algorithm 1).

``dist_sched(req, tes)`` = PD_aware → (locality_aware | load_aware):
  1. PD-aware: pick the TE *type* (disaggregated pair vs colocated) from
     the combined heatmap + the decode-length predictor (§5.3);
  2. if the surviving group is load-balanced, prefer the TE with the
     longest prefix match in the global prompt tree (§5.2);
  3. otherwise pick the least-loaded TE.

TEs are described by ``TEHandle``s — the JE-side view (type, load, local
prompt-tree index shared with the global tree). A handle is a LIVE
adapter when FLOWSERVE engines are attached (``engine`` — and, for a PD
pair, ``decode_engine``): ``refresh()`` pulls the load signal from real
engine state (queued prefill tokens, in-flight decode budget,
``Scheduler.safe_horizon`` headroom — DESIGN.md §9) instead of the
hand-fed floats the T3 simulations use.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.fleet import TEState, advance
from repro.core.heatmap import lookup
from repro.core.predictor import DecodeLengthPredictor
from repro.engine.radix_tree import RadixTree


@dataclass
class TEHandle:
    te_id: str
    te_type: str                        # "colocated" | "pd_pair"
    load: float = 0.0                   # outstanding work (tokens)
    prefill_load: float = 0.0           # refresh(): queued prefill tokens
    decode_load: float = 0.0            # refresh(): in-flight decode budget
    n_running: int = 0
    engine: object = None               # live FlowServe (or sim TE);
    #                                     pd_pair: the PRIMARY prefill engine
    decode_engine: object = None        # pd_pair: the PRIMARY decode engine
    # M:N PD groups (§4.6): a pd_pair handle may own several members per
    # side; ``engine``/``decode_engine`` stay the primaries so every 1P:1D
    # consumer is unchanged. None ⇒ the primary is the only member.
    prefill_engines: Optional[List[object]] = None
    decode_engines: Optional[List[object]] = None
    state: TEState = TEState.SERVING    # lifecycle (core/fleet.py); stubs
    #                                     and pre-§9 consumers start SERVING
    prompt_tree: RadixTree = field(default_factory=RadixTree)

    def record_prompt(self, tokens) -> None:
        self.prompt_tree.insert(tuple(tokens), self.te_id)

    # ------------------------------------------------------------ lifecycle
    def transition(self, new: TEState) -> TEState:
        """Walk the PROVISIONING→…→RELEASED machine; illegal moves raise."""
        self.state = advance(self.state, new)
        return self.state

    @property
    def admitting(self) -> bool:
        """Only SERVING TEs accept new placements (a DRAINING TE finishes
        or migrates out what it has; everything else isn't runnable)."""
        return self.state is TEState.SERVING

    # ------------------------------------------------------------ members
    def prefill_members(self) -> List[object]:
        if self.prefill_engines is not None:
            return list(self.prefill_engines)
        return [self.engine] if self.engine is not None else []

    def decode_members(self) -> List[object]:
        if self.decode_engines is not None:
            return list(self.decode_engines)
        return [self.decode_engine] if self.decode_engine is not None else []

    def grow_decode(self, engine: object) -> None:
        """§4.6 M:N scale-out: add a decode member to this PD group."""
        if self.decode_engines is None:
            self.decode_engines = self.decode_members()
        self.decode_engines.append(engine)
        if self.decode_engine is None:
            self.decode_engine = engine

    def pick_decode_member(self) -> object:
        """Algorithm-1 handoff extension (§4.6): the least-loaded decode
        member takes the next prefilled request. Load is the same signal
        ``refresh`` uses, read per member."""
        members = self.decode_members()
        if len(members) <= 1:
            return members[0] if members else None
        return min(members, key=_engine_load)

    def live_engines(self) -> List[object]:
        """The attached engines that expose real load signals."""
        return [e for e in (*self.prefill_members(), *self.decode_members())
                if e is not None and hasattr(e, "load_metrics")]

    def refresh(self) -> float:
        """Live adapter (DESIGN.md §9): recompute ``load`` from the attached
        engines' REAL state. The signal is

            load = queued_prefill_tokens + inflight_decode_tokens / headroom

        where headroom is the fused decode horizon the TE's scheduler can
        currently prove (``Scheduler.safe_horizon``): a TE in steady
        single-batch decode serves K steps per host dispatch (DESIGN.md §8),
        so its marginal decode token is cheaper than one on a TE that is
        interleaving prefill. A PD group sums every member — a sequence
        lives in exactly one of them at any time, so nothing double-counts.
        The prefill/decode split is kept (``prefill_load``/``decode_load``)
        so the scaling layer can tell decode-dominated pressure (grow the
        group's decode side, §4.6) from prefill pressure. Handles without
        live engines (the T3 sims, unit tests) keep their hand-fed ``load``
        float untouched."""
        engines = self.live_engines()
        if not engines:
            return self.load
        prefill_toks = decode_toks = 0.0
        headroom = 1.0
        n_active = 0
        for eng in engines:
            m = eng.load_metrics()
            prefill_toks += m["queued_prefill_tokens"]
            decode_toks += m["inflight_decode_tokens"]
            headroom = max(headroom, m["horizon_headroom"])
            n_active += m["n_queued"] + m["n_running"]
        self.prefill_load = prefill_toks
        self.decode_load = decode_toks
        self.load = prefill_toks + decode_toks / headroom
        self.n_running = n_active
        return self.load


def _engine_load(eng) -> float:
    """Per-member load (the refresh() signal for ONE engine)."""
    m = eng.load_metrics()
    return (m["queued_prefill_tokens"]
            + m["inflight_decode_tokens"] / max(1.0, m["horizon_headroom"]))


def _predictor_trained(pred) -> bool:
    """An online (trace-EMA) predictor with zero observations has nothing
    to say — callers fall back to the request's own estimate. Offline
    predictors (no ``n_observations``) are always trained."""
    n_obs = getattr(pred, "n_observations", None)
    return n_obs is None or n_obs() > 0


@dataclass
class SchedRequest:
    tokens: Sequence[int]
    predicted_decode: int = 128


class GlobalPromptTree:
    """JE-side: one tree per TE group; payloads are TE ids (§5.2)."""

    def __init__(self):
        self.tree = RadixTree()

    def record(self, tokens, te_id: str) -> None:
        self.tree.insert(tuple(tokens), te_id)

    def best_te(self, tokens, candidates: List[TEHandle]) -> Tuple[Optional[str], int]:
        """TE holding the longest matching prefix among candidates."""
        cand_ids = {t.te_id for t in candidates}
        best_id, best_len = None, 0
        matched, path = self.tree.match_prefix(tuple(tokens))
        # walk the matched path from deepest to shallowest; payload = te_id
        run = 0
        consumed = 0
        for node in path:
            consumed += len(node.key)
            payload = node.payload or self.tree.any_payload(node)
            if payload in cand_ids and min(consumed, matched) > best_len:
                best_id, best_len = payload, min(consumed, matched)
        return best_id, best_len


@dataclass
class DistSchedConfig:
    load_balance_threshold: float = 0.30   # max relative load spread
    min_prefix_tokens: int = 8             # ignore tiny prefix matches


class DistributedScheduler:
    """Runs inside a model-serving JE (one instance per TE group)."""

    def __init__(self, tes: List[TEHandle], combined_heatmap: np.ndarray,
                 prefill_lens, decode_ratios,
                 predictor: Optional[DecodeLengthPredictor] = None,
                 cfg: DistSchedConfig = DistSchedConfig()):
        self.tes = {t.te_id: t for t in tes}
        self.heatmap = combined_heatmap
        self.prefill_lens = prefill_lens
        self.decode_ratios = decode_ratios
        self.predictor = predictor
        self.cfg = cfg
        self.global_tree = GlobalPromptTree()
        self.decisions = {"pd_disagg": 0, "pd_colo": 0, "locality": 0, "load": 0}

    # ------------------------------------------------------ Algorithm 1
    def dist_sched(self, req: SchedRequest) -> TEHandle:
        # lifecycle gate (core/fleet.py): DRAINING/releasing TEs stop
        # admitting — they finish or migrate out what they already hold
        tes = [t for t in self.tes.values() if t.admitting]
        if not tes:             # pathological (everything draining): any
            # placement beats dropping — but NEVER route to a crashed or
            # released TE (§11: health gates both schedulers)
            tes = [t for t in self.tes.values()
                   if t.state not in (TEState.FAILED, TEState.RELEASED)]
        if not tes:
            raise RuntimeError("dist_sched: no routable TE (all failed "
                               "or released)")
        for te in tes:          # live handles pull real engine state (§9)
            te.refresh()
        tes = self.pd_aware(req, tes)
        if self._is_load_balanced(tes):
            chosen = self.locality_aware(req, tes)
        else:
            chosen = self.load_aware(req, tes)
        return chosen

    def pd_aware(self, req: SchedRequest, tes: List[TEHandle]) -> List[TEHandle]:
        p_len = len(req.tokens)
        d_len = req.predicted_decode
        if self.predictor is not None and _predictor_trained(self.predictor):
            d_len = self.predictor.predict_tokens(req.tokens)
        val = lookup(self.heatmap, self.prefill_lens, self.decode_ratios,
                     p_len, d_len)
        want = "pd_pair" if val > 0 else "colocated"
        sub = [t for t in tes if t.te_type == want]
        if not sub:                      # group has only one type
            return tes
        self.decisions["pd_disagg" if want == "pd_pair" else "pd_colo"] += 1
        return sub

    def locality_aware(self, req: SchedRequest, tes: List[TEHandle]) -> TEHandle:
        te_id, n = self.global_tree.best_te(req.tokens, tes)
        if te_id is not None and n >= self.cfg.min_prefix_tokens:
            self.decisions["locality"] += 1
            return self.tes[te_id]
        return self.load_aware(req, tes, count=False)

    def load_aware(self, req: SchedRequest, tes: List[TEHandle],
                   count: bool = True) -> TEHandle:
        if count:
            self.decisions["load"] += 1
        return min(tes, key=lambda t: t.load)

    # ------------------------------------------------------ bookkeeping
    def _is_load_balanced(self, tes: List[TEHandle]) -> bool:
        loads = [t.load for t in tes]
        if not loads or max(loads) <= 0:
            return True
        spread = (max(loads) - min(loads)) / max(max(loads), 1e-9)
        return spread <= self.cfg.load_balance_threshold

    def commit(self, req: SchedRequest, te: TEHandle) -> None:
        """Record placement: load + prompt-tree bookkeeping."""
        te.load += len(req.tokens) + req.predicted_decode
        te.n_running += 1
        self.global_tree.record(req.tokens, te.te_id)
        te.record_prompt(req.tokens)

    def complete(self, req: SchedRequest, te: TEHandle,
                 actual_decode: Optional[int] = None) -> None:
        """Release the tokens the request ACTUALLY consumed.

        ``commit`` reserved ``len(tokens) + predicted_decode``, but the real
        decode length routinely differs from the prediction; callers that
        track real progress (the live serving plane's ``refresh``, the T3
        sims that decay load as tokens generate) end up with ``te.load``
        drifting over a long run if completion subtracts the stale
        prediction — every under-predicted request leaves phantom load
        behind forever. Passing the observed decode length releases the
        consumed tokens instead; the clamp guards the over-release side."""
        consumed = len(req.tokens) + (req.predicted_decode
                                      if actual_decode is None
                                      else actual_decode)
        te.load = max(0.0, te.load - consumed)
        te.n_running = max(0, te.n_running - 1)


def round_robin_scheduler(tes: List[TEHandle]):
    """Baseline RR policy used in Figure 7's comparison. Skips TEs that
    stopped admitting (lifecycle gate) but stays degenerate otherwise."""
    state = {"i": 0}

    def pick(req: SchedRequest) -> TEHandle:
        for _ in range(len(tes)):
            te = tes[state["i"] % len(tes)]
            state["i"] += 1
            if te.admitting:
                return te
        # nothing admitting: degrade, but never onto a crashed/released TE
        routable = [t for t in tes
                    if t.state not in (TEState.FAILED, TEState.RELEASED)]
        if not routable:
            raise RuntimeError("round_robin: no routable TE (all failed "
                               "or released)")
        return routable[state["i"] % len(routable)]

    return pick
