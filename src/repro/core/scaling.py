"""Fast scaling (§6): the 5-step pipeline, pre-warmed pods/TEs, DRAM
pre-loading, and NPU-fork.

Timing models follow Table 2 / Figures 9-10: each step has a baseline
latency and an optimized path. Pre-warm pools and the DRAM page cache are
real state machines; NPU-fork moves real weight bytes through DistFlow's
broadcast (ICI = HCCS analogue, DCN = RoCE analogue), so Figure 10/11's
benchmarks measure the same code the autoscaler runs.
"""
from __future__ import annotations

import math
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.engine.distflow import (BACKENDS, BufferInfo, DistFlow,
                                   _fanout_penalty, _nbytes)


@dataclass
class ScaleTimings:
    """Baseline step latencies (seconds) — Figure 9's 'before' bars."""
    scaler_pre: float = 40.0            # pod creation / resource alloc
    te_pre_load: float = 35.0           # python startup + NPU init + HCCL
    te_pre_load_optimized: float = 22.0  # late-import + parallel init (-35%)
    te_post_load_warmup: float = 12.0   # engine warm-up profiling
    te_post_load_alloc: float = 3.0     # CPU/NPU block allocation
    te_post_load_optimized: float = 0.8  # offline profile + async alloc + dummy req
    scaler_post: float = 5.0            # global TE list propagation
    scaler_post_optimized: float = 0.5  # proactive push
    torch_init: float = 0.3             # tensor init overhead on load


@dataclass
class ModelAsset:
    name: str
    n_bytes: int                        # total weight bytes
    tp: int = 1                         # partitions (each TE loads 1/tp)


class WarmPoolMismatchError(ValueError):
    """A warm-pool entry was requested (or constructed from) under the
    wrong model-asset identity — refusing to silently build a TE from the
    wrong params (DESIGN.md §11)."""


@dataclass
class PreWarmedPod:
    pod_id: str
    busy: bool = False


@dataclass
class PreWarmedTE:
    """Model- and parallelism-agnostic pre-warmed TE (§6.1): Python/NPU/HCCL
    init already done; can be bound to any model + TP/PP/SP layout."""
    te_id: str
    bound_model: Optional[str] = None
    busy: bool = False


class WarmPool:
    """DRAM-warm tier of the cold-start ladder (DESIGN.md §10): host-pinned
    copies of REAL param pytrees, one entry per model asset.

    A hit turns TE bring-up into ``jax.device_put`` onto the TE's device
    window plus jit warmup — no model re-init and no deserialization (the
    ``DRAMPageCache`` below models the safetensors FILE cache, which still
    pays tensor-init on load; this pool holds ready tensors). The pool is
    fed two ways: predictive ``put`` by the cluster manager, and RELEASED
    TEs draining their device-resident params back to host instead of
    dropping the bytes. One entry serves ANY number of concurrent
    bring-ups — ``device_put`` only reads it, nothing consumes it."""

    def __init__(self, capacity_bytes: float = 64e9):
        self.capacity = capacity_bytes
        self.entries: "OrderedDict[str, Any]" = OrderedDict()
        self.sizes: Dict[str, int] = {}
        self.tags: Dict[str, str] = {}   # entry -> model-asset identity
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.bytes_evicted = 0

    def used(self) -> int:
        return sum(self.sizes.values())

    def put(self, name: str, params, host_copy: bool = True,
            tag: Optional[str] = None) -> bool:
        """Pin one asset's params in host DRAM, LRU-evicting until it fits.
        ``params`` may be device-resident — ``host_copy=True`` materializes
        numpy leaves (callers that already hold a host copy, e.g. a
        released TE's drained params, pass False). Returns False when the
        asset alone exceeds capacity (dropped, not partially resident).
        ``tag`` records the model-asset identity of the entry (defaults to
        ``name``); re-putting an existing entry under a DIFFERENT tag is an
        integrity violation and raises ``WarmPoolMismatchError``."""
        tag = tag or name
        if name in self.entries:
            if self.tags.get(name, name) != tag:
                raise WarmPoolMismatchError(
                    f"warm-pool entry {name!r} is tagged "
                    f"{self.tags.get(name, name)!r}; refusing re-put under "
                    f"tag {tag!r}")
            self.entries.move_to_end(name)
            return True
        n = _nbytes(params)
        if n > self.capacity:
            return False
        while self.used() + n > self.capacity and self.entries:
            victim, _ = self.entries.popitem(last=False)
            self.evictions += 1
            self.bytes_evicted += self.sizes.pop(victim)
            self.tags.pop(victim, None)
        if host_copy:
            import jax
            params = jax.tree.map(lambda a: np.asarray(a), params)
        self.entries[name] = params
        self.sizes[name] = n
        self.tags[name] = tag
        return True

    def get(self, name: str, tag: Optional[str] = None):
        """The host-pinned params for ``name`` (hit, refreshes LRU order)
        or None (miss). Hit/miss counters are the accounting the scale-out
        path reports per bring-up tier. Passing ``tag`` asserts the model-
        asset identity the caller is about to build a TE for: a mismatch
        raises ``WarmPoolMismatchError`` instead of silently handing back
        the wrong weights."""
        params = self.entries.get(name)
        if params is None:
            self.misses += 1
            return None
        if tag is not None and self.tags.get(name, name) != tag:
            raise WarmPoolMismatchError(
                f"warm-pool entry {name!r} is tagged "
                f"{self.tags.get(name, name)!r}, not {tag!r} — wrong model "
                f"asset for this bring-up")
        self.hits += 1
        self.entries.move_to_end(name)
        return params

    def hit(self, name: str) -> bool:
        """Non-counting peek (capacity planning / tier pricing)."""
        return name in self.entries

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "bytes_evicted": self.bytes_evicted,
                "resident": len(self.entries), "used_bytes": self.used()}


class DRAMPageCache:
    """Host page cache of safetensors-format weights (§6.2). The cluster
    manager pre-loads models predicted to scale."""

    def __init__(self, capacity_bytes: float = 1.5e12):
        self.capacity = capacity_bytes
        self.resident: Dict[str, ModelAsset] = {}

    def used(self) -> float:
        return sum(a.n_bytes for a in self.resident.values())

    def preload(self, asset: ModelAsset) -> bool:
        if asset.name in self.resident:
            return True
        while self.used() + asset.n_bytes > self.capacity and self.resident:
            # evict least-recently preloaded (FIFO is fine for the cache sim)
            self.resident.pop(next(iter(self.resident)))
        if asset.n_bytes > self.capacity:
            return False
        self.resident[asset.name] = asset
        return True

    def hit(self, model: str) -> bool:
        return model in self.resident


@dataclass
class LoadResult:
    path: str                           # "dram_hit" | "dram_miss" | "npu_fork_ici" | "npu_fork_dcn"
    seconds: float
    bytes_moved: int
    params: Any = None                  # live-fork path: the forked pytree


def npu_fork_live(params, cfg, dst_mesh, source: Optional[DistFlow] = None,
                  link: str = "ici", dst_device=None,
                  target_owners=(), contention: float = 1.0):
    """NPU-fork v2 (§6.3, DESIGN.md §7): fork weights PER-SHARD from a live
    sharded TE onto a new TE's mesh, replacing re-initialization.

    Each destination shard fills via ``jax.device_put`` from the source's
    resident params under the destination mesh's own sharding policy
    (``engine_param_shardings``) — the ICI analogue of per-rank HCCL
    broadcast: tp parallel links each move bytes/tp. ``link="dcn"`` prices
    the scale-out fallback over a single per-host link. ``dst_mesh=None``
    gathers onto ``dst_device`` (a tp=1 target). Returns
    ``(forked_params, LoadResult)`` and charges the transfer on ``source``'s
    DistFlow clock/log when given.
    """
    import jax

    from repro.launch import sharding as SH
    if dst_mesh is not None:
        shardings = SH.engine_param_shardings(cfg, params, dst_mesh)
        forked = jax.device_put(params, shardings)
        tp = int(dst_mesh.shape["model"])
    else:
        forked = jax.device_put(params,
                                dst_device if dst_device is not None
                                else jax.devices()[0])
        tp = 1
    n = _nbytes(params)
    backend = "ici" if link == "ici" else "dcn"
    links = tp if backend == "ici" else 1
    if source is not None:
        # charge() advances the source clock AND every linked target's, and
        # the contention multiplier lands in the clock/log too, so the
        # returned seconds and the DistFlow accounting agree
        xfer = source.charge(n, backend, links=links, fanout=contention,
                             peer_owners=tuple(target_owners))
        secs = xfer.sim_seconds
    else:
        spec = BACKENDS[backend]
        secs = spec["lat"] + (n / max(1, links) / spec["bw"]) * contention
    return forked, LoadResult(f"npu_fork_{link}", secs, n, params=forked)


def tier_seconds(asset: ModelAsset, tier: str,
                 timings: ScaleTimings = ScaleTimings()) -> float:
    """Modeled TE-Load wall for one bring-up of ``asset`` through a
    cold-start-ladder tier (DESIGN.md §10): ``fork`` = per-shard NPU-fork
    over ICI, ``warm`` = WarmPool hit → PCIe ``device_put`` (no tensor
    init), anything else = cold (tensor init + SSD read). This is the
    full-size pricing ``scale_to(pace=asset)`` holds each bring-up job to
    while the CPU sim moves smoke-scale bytes — same modeled-cost idiom as
    ``ModelLoader``, kept in closed form so concurrent rounds can overlap
    the waits without touching the DistFlow clock."""
    per_te = asset.n_bytes / max(1, asset.tp)
    if tier == "fork":
        return per_te / BACKENDS["ici"]["bw"]
    if tier == "warm":
        return per_te / BACKENDS["pcie_dram"]["bw"]
    return timings.torch_init + per_te / BACKENDS["ssd"]["bw"]


class ModelLoader:
    """TE-Load step (§6.2): local loading via PCIe (DRAM hit/miss) or
    NPU-fork over chip-to-chip links from a running TE."""

    def __init__(self, dram: DRAMPageCache, timings: ScaleTimings = ScaleTimings(),
                 warm: Optional[WarmPool] = None):
        self.dram = dram
        self.t = timings
        self.warm = warm

    def local_load(self, asset: ModelAsset, n_parallel_tes: int = 1) -> LoadResult:
        per_te = asset.n_bytes / asset.tp
        if self.warm is not None and self.warm.hit(asset.name):
            # DRAM-warm tier (DESIGN.md §10): ready tensors, no torch init —
            # bring-up is pure PCIe device_put bandwidth
            bw = BACKENDS["pcie_dram"]["bw"] / max(1, n_parallel_tes)
            return LoadResult("warm_pool", per_te / bw, int(per_te))
        if self.dram.hit(asset.name):
            bw = BACKENDS["pcie_dram"]["bw"] / max(1, n_parallel_tes)  # PCIe contention
            return LoadResult("dram_hit", self.t.torch_init + per_te / bw, int(per_te))
        bw = BACKENDS["ssd"]["bw"] / max(1, n_parallel_tes)
        self.dram.preload(asset)
        return LoadResult("dram_miss", self.t.torch_init + per_te / bw, int(per_te))

    def npu_fork(self, asset: ModelAsset, source: DistFlow,
                 targets: List[DistFlow], link: str = "ici",
                 source_busy_frac: float = 0.0,
                 payload=None, dst_mesh=None, cfg=None) -> LoadResult:
        """Broadcast weights from a running TE to `targets` (§6.2). Dedicated
        transfer engines keep interference low: `source_busy_frac` models
        prefill/decode contention on the source (Figure 11b/c).

        With a real params pytree in ``payload`` plus ``cfg`` (+ optionally
        ``dst_mesh``), this is the LIVE per-shard fork: the weights actually
        move onto the destination mesh (npu_fork_live) instead of the
        byte-counting simulation."""
        if payload is not None and cfg is not None:
            _, lr = npu_fork_live(
                payload, cfg, dst_mesh, source=source, link=link,
                target_owners=tuple(t.owner for t in targets),
                contention=1.0 + 0.15 * source_busy_frac)
            return lr
        per_te = asset.n_bytes / asset.tp
        src = BufferInfo(owner=source.owner, tier="npu",
                         payload=payload if payload is not None else b"\0")
        dsts = [BufferInfo(owner=t.owner, tier="npu", deliver=lambda _p: None)
                for t in targets]
        xfers = source.broadcast(src, dsts, backend="ici" if link == "ici" else "dcn")
        bw = BACKENDS["ici" if link == "ici" else "dcn"]["bw"]
        fanout = _fanout_penalty(len(targets))
        contention = 1.0 + 0.15 * source_busy_frac   # AICPU-offloaded: small
        secs = (per_te / bw) * fanout * contention
        return LoadResult(f"npu_fork_{link}", secs, int(per_te) * len(targets))

    def theoretical(self, asset: ModelAsset) -> float:
        return (asset.n_bytes / asset.tp) / BACKENDS["pcie_dram"]["bw"]


@dataclass
class LoadSpreadTrigger:
    """Serving-plane scale-out trigger (DESIGN.md §9): fire when the
    relative load spread across the fleet's TEs stays above ``threshold``
    for ``patience`` consecutive observations. Firing is one-shot per
    breach: the trigger disarms until the spread next drops below the
    threshold — a freshly forked TE joins with zero load, which KEEPS the
    spread high, so re-arming on recovery (not on time) is what prevents a
    fork storm — and ``max_fires`` caps total fires for bounded fleets.

    ``observe`` reports a capacity DEFICIT (how many TEs short the fleet
    is), not a boolean: with ``te_capacity`` set, a burst that needs four
    more TEs requests the whole fork tree in ONE fire instead of one fork
    per re-arm cycle. 0 = don't scale; truthiness is backward-compatible
    with the old bool contract."""

    threshold: float = 0.5              # (max-min)/max relative spread
    patience: int = 8                   # consecutive breached observations
    min_load: float = 1.0               # ignore spread across near-idle TEs
    max_fires: int = 1
    te_capacity: Optional[float] = None  # tokens of work one TE absorbs
    breach_steps: int = 0
    armed: bool = True
    fires: int = 0
    last_deficit: int = 0

    def observe(self, loads: List[float]) -> int:
        """Feed one observation of the fleet's live loads; returns the TE
        deficit — 0 ⇒ hold, k ≥ 1 ⇒ scale out by k (the caller forks via
        ``FastScaler`` / NPU-fork; k > 1 plans a fork tree)."""
        peak = max(loads) if loads else 0.0
        spread = 0.0 if peak < self.min_load \
            else (peak - min(loads)) / peak
        if spread <= self.threshold:
            self.breach_steps = 0
            self.armed = True
            return 0
        if not self.armed or self.fires >= self.max_fires:
            return 0
        self.breach_steps += 1
        if self.breach_steps < self.patience:
            return 0
        self.armed = False
        self.breach_steps = 0
        self.fires += 1
        if self.te_capacity is None:
            deficit = 1
        else:
            want = math.ceil(sum(loads) / max(1e-9, self.te_capacity))
            deficit = max(1, want - len(loads))
        self.last_deficit = deficit
        return deficit


@dataclass
class DrainTrigger:
    """Scale-IN trigger (DESIGN.md §9) — the low-watermark twin of
    ``LoadSpreadTrigger``: fire when the fleet's mean load per live TE
    stays below ``low_watermark`` for ``patience`` consecutive
    observations while more than ``min_serving`` TEs are serving. The
    caller drains one TE (stop admissions → finish/migrate out → release
    its device window).

    Firing is one-shot per drain: the trigger disarms when it fires and
    re-arms only when the caller reports the drain COMPLETE (``rearm()``,
    called at RELEASED) or the mean load recovers above the watermark —
    a draining TE's load migrating onto its peers keeps the fleet mean
    low, so time-based re-arming would drain the whole fleet in one idle
    spell. Mutual exclusion with the scale-out trigger is owned by the
    serving plane: neither trigger is even fed while the other's action
    is in flight (no fork-while-draining races)."""

    low_watermark: float = 2.0          # mean tokens of work per live TE
    patience: int = 8                   # consecutive low observations
    min_serving: int = 1                # never drain below this many TEs
    max_fires: int = 64
    resurge_factor: float = 4.0         # resurgence = mean > factor*watermark
    breach_steps: int = 0
    armed: bool = True
    fires: int = 0

    def observe(self, loads: List[float], n_serving: Optional[int] = None
                ) -> bool:
        """Feed one observation of the live fleet's loads; True ⇒ drain one
        TE now. ``n_serving`` defaults to ``len(loads)``."""
        n = len(loads) if n_serving is None else n_serving
        if n <= self.min_serving:
            self.breach_steps = 0
            return False
        mean = sum(loads) / max(1, len(loads))
        if mean > self.low_watermark:
            self.breach_steps = 0
            self.armed = True
            return False
        if not self.armed or self.fires >= self.max_fires:
            return False
        self.breach_steps += 1
        if self.breach_steps < self.patience:
            return False
        self.armed = False
        self.breach_steps = 0
        self.fires += 1
        return True

    def rearm(self) -> None:
        """Report the in-flight drain finished (TE reached RELEASED)."""
        self.armed = True

    def resurgent(self, loads: List[float]) -> bool:
        """Load-resurgence check for drain-CANCEL (DESIGN.md §10): True
        when the mean load across the still-serving TEs has shot past
        ``resurge_factor`` × the low watermark — the capacity being
        drained is needed after all, so the plane legally transitions
        the DRAINING TE back to SERVING instead of releasing it."""
        if not loads:
            return False
        return (sum(loads) / len(loads)
                > self.resurge_factor * self.low_watermark)


@dataclass
class ScaleEvent:
    te_id: str
    steps: Dict[str, float]
    total: float
    path: str


class FastScaler:
    """End-to-end scaling pipeline (Figure 8): Scaler-Pre → TE-Pre-Load →
    TE-Load → TE-Post-Load → Scaler-Post, with every §6 optimization
    toggleable so Figure 9's before/after is reproducible."""

    def __init__(self, dram: DRAMPageCache, timings: ScaleTimings = ScaleTimings(),
                 n_prewarm_pods: int = 4, n_prewarm_tes: int = 4,
                 warm: Optional[WarmPool] = None):
        self.t = timings
        self.dram = dram
        self.warm = warm
        self.loader = ModelLoader(dram, timings, warm=warm)
        self.pods = [PreWarmedPod(f"pod-{i}") for i in range(n_prewarm_pods)]
        self.tes = [PreWarmedTE(f"pw-te-{i}") for i in range(n_prewarm_tes)]
        self.events: List[ScaleEvent] = []

    def _grab_pod(self) -> Optional[PreWarmedPod]:
        for p in self.pods:
            if not p.busy:
                p.busy = True
                return p
        return None

    def _grab_te(self, model: str) -> Optional[PreWarmedTE]:
        # prefer a pre-warmed TE already bound to this model's DRAM preload
        for te in self.tes:
            if not te.busy and te.bound_model == model:
                te.busy = True
                return te
        for te in self.tes:
            if not te.busy:
                te.busy = True
                return te
        return None

    def scale_one(self, asset: ModelAsset, optimized: bool = True,
                  source: Optional[DistFlow] = None,
                  targets: Optional[List[DistFlow]] = None,
                  link: str = "ici", n_parallel: int = 1,
                  preloaded: Optional[LoadResult] = None) -> ScaleEvent:
        """Run the 5-step pipeline. ``preloaded`` lets a caller that already
        executed the TE-Load step (the serving plane's live
        ``FlowServe.fork_from``, DESIGN.md §9) price the pipeline around it
        without charging the transfer fabric twice."""
        steps: Dict[str, float] = {}
        # 1. Scaler-Pre
        pod = self._grab_pod() if optimized else None
        steps["scaler_pre"] = 0.2 if pod is not None else self.t.scaler_pre
        # 2. TE-Pre-Load
        te = self._grab_te(asset.name) if optimized else None
        if te is not None:
            steps["te_pre_load"] = 0.5                    # pool hit
        else:
            steps["te_pre_load"] = (self.t.te_pre_load_optimized if optimized
                                    else self.t.te_pre_load)
        # 3. TE-Load
        if preloaded is not None:
            lr = preloaded
        elif source is not None and targets:
            lr = self.loader.npu_fork(asset, source, targets, link=link)
        else:
            lr = self.loader.local_load(asset, n_parallel_tes=n_parallel)
        steps["te_load"] = lr.seconds
        # 4. TE-Post-Load
        steps["te_post_load"] = (self.t.te_post_load_optimized if optimized else
                                 self.t.te_post_load_warmup + self.t.te_post_load_alloc)
        # 5. Scaler-Post
        steps["scaler_post"] = (self.t.scaler_post_optimized if optimized
                                else self.t.scaler_post)
        ev = ScaleEvent(te_id=te.te_id if te else f"cold-te-{len(self.events)}",
                        steps=steps, total=sum(steps.values()), path=lr.path)
        self.events.append(ev)
        return ev

    def release(self, te_id: str) -> None:
        for te in self.tes:
            if te.te_id == te_id:
                te.busy = False
        for p in self.pods:
            p.busy = False
