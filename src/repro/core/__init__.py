from repro.core.abstractions import (UserRequest, RequestType, Job, JobKind,  # noqa: F401
                                     Task, TaskKind, Status, decompose)
from repro.core.scheduling import (DistributedScheduler, TEHandle, SchedRequest,  # noqa: F401
                                   GlobalPromptTree, round_robin_scheduler)
from repro.core.cluster import ClusterManager, JobExecutor, TaskExecutor, AutoscalerConfig  # noqa: F401
from repro.core.scaling import (FastScaler, DRAMPageCache, ModelAsset, ModelLoader,  # noqa: F401
                                ScaleTimings, WarmPool, LoadSpreadTrigger, DrainTrigger,
                                tier_seconds)
from repro.core.heatmap import HeatmapStudy  # noqa: F401
from repro.core.predictor import (PredictorConfig, DecodeLengthPredictor,  # noqa: F401
                                  train_predictor, synth_trace)
