"""Deterministic fault injection for the elastic fleet (DESIGN.md §11).

DeepServe's production claim rests on the plane surviving component
failure (§7: detect → contain → reboot/replace); λScale re-routes
in-flight work when a node in its multicast tree dies. This module makes
those failure modes REPRODUCIBLE: a ``FaultPlan`` is a seeded list of
``FaultSpec``s evaluated at hook points inside the live engines —

* ``FlowServe.step``       — TE crash at step N / during PREFILL, plus
  straggler delay (the TE stalls but does not die);
* ``FlowServe.migrate_out`` — TE crash MID-MIGRATION (the source dies
  after the destination imported, before the source acked/cleaned up);
* ``FlowServe.fork_from``  — transient fork failure (``ForkFault``, the
  scale-out path retries with backoff + an alternative source) or a
  source crash mid-fork;
* ``DistFlow.transfer(_sharded)`` — transient transfer failure
  (``TransferFault``): the migration is voided on the wire, both
  endpoints' request state is restored, and the pump retries with
  capped exponential backoff.

A crash surfaces as ``TEFailureError`` out of the unit's step; the
serving plane's quarantine path (``ServingJobEngine._on_unit_failure``)
turns it into FAILED → RELEASED plus request recovery. Every fired spec
is recorded in ``FaultPlan.injected`` and the plan's ``seed`` makes
victim choice and bench runs replayable.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.engine.distflow import TransferFault  # noqa: F401  (re-export)


class TEFailureError(RuntimeError):
    """A TE (or one engine of its unit) crashed — the whole fleet unit is
    quarantined by the serving plane."""

    def __init__(self, msg: str, te: Optional[str] = None):
        super().__init__(msg)
        self.te = te


class ForkFault(RuntimeError):
    """Transient NPU-fork failure: the fork did not happen, the source is
    fine — retry (with backoff / an alternative source)."""


class AdmissionRejected(RuntimeError):
    """Admission control shed this request (bounded queue under capacity
    loss, DESIGN.md §11) — explicit rejection instead of unbounded
    backlog."""

    def __init__(self, msg: str, req_id: str = ""):
        super().__init__(msg)
        self.req_id = req_id


def backoff_s(attempt: int, base: float = 0.005, cap: float = 0.1) -> float:
    """Capped exponential backoff delay for retry attempt ``attempt``."""
    return min(cap, base * (2 ** max(0, attempt)))


@dataclass
class FaultSpec:
    """One injectable fault. ``te`` matches an engine name exactly or by
    prefix (``"te-pd0"`` hits every member of that group); None matches
    any engine. ``at_step`` arms the spec once the engine's local step
    counter reaches it. ``phase`` scopes a crash: "step" (any step),
    "prefill" (only while the engine holds queued prefill work),
    "migration" (inside ``migrate_out``) or "fork" (as a fork source).
    ``count`` is the firing budget (transient faults fire N times then
    clear). ``delay_s`` is the straggler stall per firing."""

    kind: str                       # "te_crash" | "xfer_fail" | "fork_fail"
    #                                 | "straggler"
    te: Optional[str] = None
    at_step: Optional[int] = None
    phase: str = "step"
    count: int = 1
    delay_s: float = 0.0


class FaultPlan:
    """Seeded, thread-safe fault schedule shared by every engine of one
    plane (hooks run on fleet worker threads)."""

    KINDS = ("te_crash", "xfer_fail", "fork_fail", "straggler")

    def __init__(self, seed: int = 0, specs: Sequence[FaultSpec] = ()):
        self.seed = int(seed)
        self.specs: List[FaultSpec] = list(specs)
        for spec in self.specs:
            if spec.kind not in self.KINDS:
                raise ValueError(f"unknown fault kind {spec.kind!r}")
        self.injected: List[Dict[str, Any]] = []
        self._rng = np.random.RandomState(self.seed)
        self._lock = threading.Lock()

    def choose_victim(self, names: Sequence[str]) -> str:
        """Seeded deterministic victim pick (sorted for order stability)."""
        names = sorted(names)
        return names[int(self._rng.randint(len(names)))]

    def add(self, spec: FaultSpec) -> "FaultPlan":
        if spec.kind not in self.KINDS:
            raise ValueError(f"unknown fault kind {spec.kind!r}")
        with self._lock:
            self.specs.append(spec)
        return self

    # ------------------------------------------------------------ matching
    def _fire(self, kind: str, te: Optional[str], step: Optional[int],
              phase: Optional[str] = None) -> Optional[FaultSpec]:
        """Find + consume one firing of a matching spec; records it."""
        with self._lock:
            for spec in self.specs:
                if spec.kind != kind or spec.count <= 0:
                    continue
                if spec.te is not None and te is not None \
                        and te != spec.te and not te.startswith(spec.te):
                    continue
                if spec.at_step is not None and step is not None \
                        and step < spec.at_step:
                    continue
                if kind == "te_crash" and phase is not None \
                        and spec.phase != phase:
                    continue
                spec.count -= 1
                self.injected.append({"kind": kind, "te": te, "step": step,
                                      "phase": phase or spec.phase})
                return spec
        return None

    # ------------------------------------------------------------ hooks
    def on_step(self, engine) -> None:
        """``FlowServe.step`` entry hook: straggler stall, then crash-at-
        step / crash-during-PREFILL. Raises ``TEFailureError`` on crash."""
        name, step = engine.name, engine.steps
        spec = self._fire("straggler", name, step)
        if spec is not None and spec.delay_s > 0:
            time.sleep(spec.delay_s)
        phases = ["step"]
        if engine.scheduler.queued_seqs():
            phases.insert(0, "prefill")
        for phase in phases:
            if self._fire("te_crash", name, step, phase) is not None:
                raise TEFailureError(
                    f"injected crash of {name} at step {step} ({phase})",
                    te=name)

    def on_migration(self, src_engine, dst_name: str) -> None:
        """``migrate_out`` hook (source side, after the destination
        imported): the source dies mid-migration."""
        name = src_engine.name
        if self._fire("te_crash", name, src_engine.steps,
                      "migration") is not None:
            raise TEFailureError(
                f"injected crash of {name} mid-migration to {dst_name}",
                te=name)

    def on_fork(self, source) -> None:
        """``fork_from`` hook: transient ``ForkFault`` or a source crash
        mid-fork (``TEFailureError``)."""
        name = source.name
        if self._fire("fork_fail", name, source.steps) is not None:
            raise ForkFault(f"injected transient fork failure on {name}")
        if self._fire("te_crash", name, source.steps, "fork") is not None:
            raise TEFailureError(
                f"injected crash of fork source {name}", te=name)

    def xfer_hook(self, src_owner: str, dst_owner: str, n_bytes: int) -> None:
        """``DistFlow.transfer(_sharded)`` hook: transient wire failure on
        a migration whose src OR dst matches the spec."""
        for owner in (src_owner, dst_owner):
            if self._fire("xfer_fail", owner, None) is not None:
                raise TransferFault(
                    f"injected transfer failure {src_owner} -> {dst_owner} "
                    f"({n_bytes} bytes)")

    # ------------------------------------------------------------ wiring
    def attach(self, engine) -> None:
        """Wire this plan into one engine (step/migration/fork hooks via
        ``engine.fault_plan``, wire faults via the DistFlow hook)."""
        engine.fault_plan = self
        engine.distflow.fault_hook = self.xfer_hook

    # ------------------------------------------------------------ stats
    def fired(self, kind: Optional[str] = None) -> int:
        with self._lock:
            return sum(1 for f in self.injected
                       if kind is None or f["kind"] == kind)
