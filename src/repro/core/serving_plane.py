"""The live serving plane (DESIGN.md §9): a model-serving JE that owns a
fleet of REAL FLOWSERVE TEs and routes requests through Algorithm 1.

This is the layer that composes everything below it into the paper's
system shape (§3): an external ``UserRequest`` decomposes into a serving
``Job`` whose ``Task``s (prefill/decode or colocated) land on live
engines —

* **PD-disaggregated pairs**: a prefill-mode TE runs chunked prefill,
  then each finished request's KV migrates to the pair's decode-mode TE
  over ``DistFlow.transfer_sharded`` (``FlowServe.migrate_out``, the §7
  overlap path) — pumped every JE step, i.e. the steady path rather than
  a test fixture;
* **PD-colocated TEs**: one engine runs both phases with chunked-prefill
  interleaving.

Placement is ``DistributedScheduler.dist_sched`` (Algorithm 1) over live
``TEHandle`` adapters whose load signal comes from real engine state
(queued prefill tokens, in-flight decode budget, fused-horizon headroom
— ``FlowServe.load_metrics``), or ``round_robin_scheduler`` as the
degenerate baseline policy. When the fleet's load spread stays above a
threshold (``LoadSpreadTrigger``), the plane scales out: ``FastScaler``
prices the 5-step pipeline while ``FlowServe.fork_from`` NPU-forks the
weights from a live TE onto the new one (§6.3).

TEs occupy DISJOINT device windows when ``tp > 1``
(``EngineConfig.device_offset``), so PD migration and NPU-fork move
bytes between genuinely different device sets.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional

from repro.core.abstractions import (Job, RequestType, Status, TaskKind,
                                     UserRequest, decompose)
from repro.core.scaling import FastScaler, LoadSpreadTrigger, ModelAsset
from repro.core.scheduling import (DistSchedConfig, DistributedScheduler,
                                   SchedRequest, TEHandle,
                                   round_robin_scheduler)
from repro.engine import EngineConfig, FlowServe, Request, SamplingParams
from repro.engine.flowserve import Completion


@dataclass
class TopologySpec:
    """Fleet shape: ``pd`` disaggregated 1P+1D pairs plus ``colo``
    PD-colocated TEs, each TE an SPMD program over ``tp`` devices."""

    pd: int = 0
    colo: int = 1
    tp: int = 1

    @classmethod
    def parse(cls, spec: str) -> "TopologySpec":
        """Parse a ``--topology`` string: ``"pd=2,colo=2"``,
        ``"pd=1,colo=1,tp=2"``."""
        kw: Dict[str, int] = {}
        for part in spec.split(","):
            if not part.strip():
                continue
            key, sep, val = part.partition("=")
            key = key.strip()
            if not sep or key not in ("pd", "colo", "tp"):
                raise ValueError(f"bad topology entry {part!r} in {spec!r} "
                                 "(want pd=N,colo=N[,tp=N])")
            kw[key] = int(val)
        topo = cls(**kw)
        if topo.pd + topo.colo < 1:
            raise ValueError(f"empty topology {spec!r}")
        return topo

    def n_engines(self) -> int:
        return 2 * self.pd + self.colo


@dataclass
class _PlaneRequest:
    """JE-side per-request record tying the §3 abstractions together."""

    job: Job
    sreq: SchedRequest
    handle: TEHandle
    engine_req: Request
    submitted: float = field(default_factory=time.monotonic)


class ServingJobEngine:
    """Model-serving JE over a live FLOWSERVE fleet (DESIGN.md §9)."""

    def __init__(self, bundle, params, topology: TopologySpec, *,
                 heatmap, prefill_lens, decode_ratios, predictor=None,
                 policy: str = "dist_sched",
                 ecfg: Optional[EngineConfig] = None,
                 dcfg: Optional[DistSchedConfig] = None,
                 scaler: Optional[FastScaler] = None,
                 trigger: Optional[LoadSpreadTrigger] = None):
        if policy not in ("dist_sched", "round_robin"):
            raise ValueError(f"unknown policy {policy!r}")
        self.bundle = bundle
        self.params = params
        self.topology = topology
        base = ecfg if ecfg is not None else EngineConfig()
        # TopologySpec.tp and EngineConfig.tp describe the same thing;
        # whichever side was set wins, conflicting non-defaults are an error
        if base.tp != topology.tp:
            if base.tp == 1:
                base = replace(base, tp=topology.tp)
            elif topology.tp == 1:
                topology.tp = base.tp
            else:
                raise ValueError(f"conflicting tp: EngineConfig.tp={base.tp} "
                                 f"vs TopologySpec.tp={topology.tp}")
        self._base_ecfg = base
        self._offset_cursor = 0
        self.engines: List[FlowServe] = []
        self.policy = policy
        self.scaler = scaler
        self.trigger = trigger
        self.scale_events: List[Dict[str, Any]] = []
        self.steps = 0

        handles: List[TEHandle] = []
        for i in range(topology.pd):
            pe = self._spawn(f"te-pd{i}-p", "prefill")
            de = self._spawn(f"te-pd{i}-d", "decode")
            handles.append(TEHandle(f"te-pd{i}", "pd_pair",
                                    engine=pe, decode_engine=de))
        for i in range(topology.colo):
            ce = self._spawn(f"te-colo{i}", "colocated")
            handles.append(TEHandle(f"te-colo{i}", "colocated", engine=ce))
        # one M:N DistFlow peer group over the whole fleet (§4.6): PD pairs
        # migrate KV, NPU-fork broadcasts weights, all on linked clocks
        for i, eng in enumerate(self.engines):
            eng.distflow.link_cluster(
                [p.distflow for p in self.engines[i + 1:]])

        self._handles = handles           # shared list: RR sees scale-outs
        self.scheduler = DistributedScheduler(
            handles, heatmap, prefill_lens, decode_ratios,
            predictor=predictor,
            cfg=dcfg if dcfg is not None else DistSchedConfig())
        self._rr = round_robin_scheduler(self._handles) \
            if policy == "round_robin" else None
        self.requests: Dict[str, _PlaneRequest] = {}
        self.jobs: Dict[str, Job] = {}
        self.completions: List[Completion] = []
        # per-pair queue of prefilled requests waiting on decode-TE capacity
        self._migrate_pending: Dict[str, deque] = {
            h.te_id: deque() for h in handles if h.te_type == "pd_pair"}

    # ------------------------------------------------------------ fleet
    def _spawn(self, name: str, mode: str) -> FlowServe:
        ecfg = replace(self._base_ecfg, mode=mode,
                       device_offset=self._next_offset())
        te = FlowServe(self.bundle, self.params, ecfg, name=name)
        self.engines.append(te)
        return te

    def _next_offset(self) -> int:
        """Disjoint per-TE device windows under TP (DESIGN.md §7). With
        tp=1 every TE shares device 0 (offsets are meaningless); when the
        fleet outgrows the visible devices, later TEs fall back to window 0
        (simulated co-residence) rather than failing bring-up."""
        tp = self.topology.tp
        if tp <= 1:
            return 0
        import jax
        if self._offset_cursor + tp <= jax.device_count():
            off = self._offset_cursor
            self._offset_cursor += tp
            return off
        return 0

    @property
    def handles(self) -> List[TEHandle]:
        return list(self._handles)

    # ------------------------------------------------------------ intake
    def submit(self, tokens, sampling: Optional[SamplingParams] = None,
               predicted_decode: Optional[int] = None,
               request: Optional[UserRequest] = None) -> str:
        """request → job → task(s) → TE (Algorithm 1 or round-robin).

        Returns the request id; its ``Completion`` surfaces from ``step``
        once the decode finishes (on the pair's decode TE or the colocated
        TE). ``predicted_decode`` defaults to the sampling budget; a
        ``DecodeLengthPredictor`` attached to the scheduler refines it
        inside ``pd_aware``.
        """
        sampling = sampling if sampling is not None else SamplingParams()
        if request is None:
            request = UserRequest(rtype=RequestType.CHAT,
                                  payload={"tokens": list(tokens),
                                           "max_new_tokens":
                                               sampling.max_new_tokens})
        job = decompose(request)[0]
        job.status = Status.RUNNING
        self.jobs[job.job_id] = job
        sreq = SchedRequest(tokens=list(tokens),
                            predicted_decode=sampling.max_new_tokens
                            if predicted_decode is None else predicted_decode)
        if self._rr is not None:
            handle = self._rr(sreq)
        else:
            handle = self.scheduler.dist_sched(sreq)
            self.scheduler.commit(sreq, handle)
        if handle.te_type == "pd_pair":
            tp_ = job.spawn(TaskKind.PREFILL, tokens=list(tokens))
            tp_.te_id, tp_.status = handle.engine.name, Status.RUNNING
            td = job.spawn(TaskKind.DECODE)
            td.te_id = handle.decode_engine.name
        else:
            tc = job.spawn(TaskKind.COLOCATED, tokens=list(tokens))
            tc.te_id, tc.status = handle.engine.name, Status.RUNNING
        ereq = Request(prompt_tokens=list(tokens), sampling=sampling,
                       req_id=request.req_id)
        ereq.arrival = request.arrival      # TTFT from EXTERNAL arrival
        handle.engine.add_request(ereq)
        self.requests[request.req_id] = _PlaneRequest(job, sreq, handle, ereq)
        return request.req_id

    # ------------------------------------------------------------ drive
    def step(self) -> List[Completion]:
        """One JE iteration: step every TE, pump each PD pair's handoff
        (prefill-done → ``migrate_out`` → decode TE, gated on destination
        page capacity), harvest completions, feed the scale-out trigger."""
        out: List[Completion] = []
        for handle in self._handles:
            pe, de = handle.engine, handle.decode_engine
            if de is not None:                       # PD pair
                if pe.has_work():
                    pe.step()
                pending = self._migrate_pending[handle.te_id]
                pending.extend(pe.pop_migratable())
                while pending and self._try_migrate(pe, de, pending[0]):
                    pending.popleft()
                if de.has_work():
                    out.extend(de.step())
            elif pe.has_work():                      # colocated
                out.extend(pe.step())
        for comp in out:
            self._on_complete(comp)
        self.completions.extend(out)
        self._maybe_scale()
        self.steps += 1
        return out

    def has_work(self) -> bool:
        return bool(self.requests)

    def run_to_completion(self, max_steps: int = 20000) -> List[Completion]:
        out: List[Completion] = []
        for _ in range(max_steps):
            if not self.has_work():
                break
            out.extend(self.step())
        return out

    # ------------------------------------------------------------ PD pump
    def _try_migrate(self, pe: FlowServe, de: FlowServe, req_id: str) -> bool:
        """Hand one prefilled request to the pair's decode TE. Returns
        False when the destination pool lacks pages for the KV run — the
        request stays queued on the prefill side (backpressure) and the
        pump retries next step."""
        seq = pe._seqs.get(req_id)
        if seq is None:
            return True                   # released upstream; drop
        if de.pool is not None:
            # cheap pre-gate; cached (reclaimable) pages count because the
            # import path evicts them coherently through the RTC
            free = de.pool.free_page_count() + len(de.pool.reclaimable())
            if len(seq.pages) > free:
                return False
        # import_request signals exhaustion (pages or slots) by raising
        # BEFORE committing destination state and before the source
        # releases — the request parks on the prefill side and retries
        from repro.engine.kv_cache import OutOfPagesError
        try:
            pe.migrate_out(req_id, de)
        except OutOfPagesError:
            return False
        task = self._find_task(req_id, TaskKind.PREFILL)
        if task is not None:
            task.status = Status.DONE
        decode_task = self._find_task(req_id, TaskKind.DECODE)
        if decode_task is not None:
            decode_task.status = Status.RUNNING
        return True

    def _find_task(self, req_id: str, kind: TaskKind):
        rec = self.requests.get(req_id)
        if rec is None:
            return None
        for task in rec.job.tasks:
            if task.kind == kind:
                return task
        return None

    # ------------------------------------------------------------ harvest
    def _on_complete(self, comp: Completion) -> None:
        rec = self.requests.pop(comp.req_id, None)
        if rec is None:
            return
        for task in rec.job.tasks:
            task.status = Status.DONE
        rec.job.status = Status.DONE
        rec.job.result = comp
        if self._rr is None:
            # release the ACTUAL consumption, not the prediction — the
            # complete() drift fix only helps if callers pass actuals
            self.scheduler.complete(rec.sreq, rec.handle,
                                    actual_decode=len(comp.tokens))

    # ------------------------------------------------------------ scaling
    def _maybe_scale(self) -> None:
        if self.trigger is None:
            return
        loads = [h.refresh() for h in self._handles]
        if not self.trigger.observe(loads):
            return
        # NPU-fork a new colocated TE from the least-loaded live engine
        # (its ICI links are the freest; §6.3). FastScaler prices the
        # 5-step bring-up pipeline around the same fork.
        src_handle = min(self._handles, key=lambda h: h.load)
        src_engine = src_handle.decode_engine or src_handle.engine
        name = f"te-scale{len(self.scale_events)}"
        ecfg = replace(self._base_ecfg, mode="colocated",
                       device_offset=self._next_offset())
        te = FlowServe.fork_from(src_engine, ecfg, name=name)
        for eng in self.engines:
            eng.distflow.link_cluster([te.distflow])
        self.engines.append(te)
        event = None
        if self.scaler is not None:
            from repro.core.scaling import LoadResult
            from repro.engine.distflow import _nbytes
            asset = ModelAsset(name=getattr(self.bundle.cfg, "name", "model"),
                               n_bytes=_nbytes(self.params),
                               tp=max(1, self.topology.tp))
            # fork_from already moved the weights and charged DistFlow;
            # hand its transfer to the pipeline as the TE-Load step
            xfer = src_engine.distflow.log[-1]
            event = self.scaler.scale_one(
                asset, optimized=True,
                preloaded=LoadResult("npu_fork_ici", xfer.sim_seconds,
                                     xfer.n_bytes))
        handle = TEHandle(name, "colocated", engine=te)
        self._handles.append(handle)
        self.scheduler.tes[name] = handle
        self.scale_events.append({"step": self.steps, "te_id": name,
                                  "source": src_engine.name, "event": event})

    # ------------------------------------------------------------ stats
    def fleet_metrics(self) -> Dict[str, Dict[str, float]]:
        """Per-handle live load snapshot (refreshes every handle)."""
        out = {}
        for handle in self._handles:
            handle.refresh()
            out[handle.te_id] = {"load": handle.load,
                                 "n_running": handle.n_running,
                                 "type": handle.te_type}
        return out
