"""The live serving plane (DESIGN.md §9): a model-serving JE that owns an
ELASTIC fleet of REAL FLOWSERVE TEs and routes requests through Algorithm 1.

This is the layer that composes everything below it into the paper's
system shape (§3): an external ``UserRequest`` decomposes into a serving
``Job`` whose ``Task``s (prefill/decode or colocated) land on live
engines —

* **PD groups (M:N, §4.6)**: ``pd=N`` builds N 1P:1D pairs; ``pd=NpXd``
  builds a group whose N prefill TEs feed X decode TEs. Each finished
  prefill's KV migrates to the group's LEAST-LOADED decode member over
  ``DistFlow.transfer_sharded`` (``FlowServe.migrate_out``, the §7 overlap
  path) — pumped every JE step, i.e. the steady path rather than a test
  fixture;
* **PD-colocated TEs**: one engine runs both phases with chunked-prefill
  interleaving.

The fleet is a real RUNTIME, not a for-loop (core/fleet.py):

* **per-TE executors** — with ``fleet_threads > 1`` every fleet unit (one
  PD group or one colocated TE) steps on its own pinned worker thread;
  ``step()`` is submit/collect over a barrier-free event queue, so
  engines overlap wall-clock work. ``FlowServe`` entry points are
  executor-safe (per-engine RLock, dual-lock migration);
* **lifecycle** — every TE walks ``PROVISIONING → WARMING → SERVING ⇄
  DRAINING → RELEASED``; only SERVING TEs admit placements;
* **scale-out** (``LoadSpreadTrigger``): sustained load spread NPU-forks
  capacity from a live TE (§6.3) — a whole colocated TE, or just a new
  decode member for the hottest PD group when the fleet's pressure is
  decode-dominated (shortP/longD, §4.6);
* **scale-IN** (``DrainTrigger``): sustained low watermark drains a TE —
  admissions stop, in-flight decodes finish or migrate out over the §7
  sharded path — then releases its device window for reuse by a future
  fork. The two triggers are mutually exclusive per TE: nothing forks
  while a drain is in flight and vice versa.

Placement is ``DistributedScheduler.dist_sched`` (Algorithm 1) over live
``TEHandle`` adapters whose load signal comes from real engine state
(``FlowServe.load_metrics``), with ``SchedRequest.predicted_decode`` from
the trace-trained EMA predictor (``TraceEMAPredictor``) rather than the
sampling budget; ``round_robin_scheduler`` stays the degenerate baseline.

TEs occupy DISJOINT device windows when ``tp > 1``
(``EngineConfig.device_offset``), so PD migration and NPU-fork move bytes
between genuinely different device sets — and a RELEASED TE's window goes
back on the free list.
"""
from __future__ import annotations

import re
import threading
import time

import jax
from collections import deque
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Tuple

from repro.core.abstractions import (Job, RequestType, Status, TaskKind,
                                     UserRequest, decompose)
from repro.core.faults import (AdmissionRejected, FaultPlan, ForkFault,
                               TEFailureError, TransferFault, backoff_s)
from repro.core.fleet import FleetExecutor, TEState
from repro.core.predictor import TraceEMAPredictor
from repro.core.scaling import (DrainTrigger, FastScaler, LoadSpreadTrigger,
                                ModelAsset, WarmPool, tier_seconds)
from repro.core.scheduling import (DistSchedConfig, DistributedScheduler,
                                   SchedRequest, TEHandle, _engine_load,
                                   _predictor_trained,
                                   round_robin_scheduler)
from repro.engine import EngineConfig, FlowServe, Request, SamplingParams
from repro.engine.flowserve import Completion

_PD_GROUP_RE = re.compile(r"^(\d+)p(\d+)d$")


@dataclass
class TopologySpec:
    """Fleet shape: PD groups plus ``colo`` PD-colocated TEs, each TE an
    SPMD program over ``tp`` devices. ``pd=N`` means N disaggregated
    1P:1D pairs; ``pd=NpXd`` (e.g. ``pd=1p2d``) means one M:N group of N
    prefill TEs feeding X decode TEs (§4.6)."""

    pd: int = 0
    colo: int = 1
    tp: int = 1
    pd_groups: List[Tuple[int, int]] = field(default_factory=list)

    @classmethod
    def parse(cls, spec: str) -> "TopologySpec":
        """Parse a ``--topology`` string: ``"pd=2,colo=2"``,
        ``"pd=1p2d,colo=1"``, ``"pd=1,colo=1,tp=2"``."""
        kw: Dict[str, Any] = {}
        groups: List[Tuple[int, int]] = []
        for part in spec.split(","):
            if not part.strip():
                continue
            key, sep, val = part.partition("=")
            key = key.strip()
            if not sep or key not in ("pd", "colo", "tp"):
                raise ValueError(f"bad topology entry {part!r} in {spec!r} "
                                 "(want pd=N|pd=NpXd,colo=N[,tp=N])")
            m = _PD_GROUP_RE.match(val.strip()) if key == "pd" else None
            if m is not None:
                n_p, n_d = int(m.group(1)), int(m.group(2))
                if n_p < 1 or n_d < 1:
                    raise ValueError(f"empty PD group {val!r} in {spec!r}")
                groups.append((n_p, n_d))
            else:
                kw[key] = int(val)
        topo = cls(pd_groups=groups, **kw)
        if not topo.groups() and topo.colo < 1:
            raise ValueError(f"empty topology {spec!r}")
        return topo

    def groups(self) -> List[Tuple[int, int]]:
        """(n_prefill, n_decode) per PD group; ``pd=N`` ⇒ N (1,1) pairs."""
        return self.pd_groups + [(1, 1)] * self.pd

    def n_engines(self) -> int:
        return sum(p + d for p, d in self.groups()) + self.colo


@dataclass
class _PlaneRequest:
    """JE-side per-request record tying the §3 abstractions together."""

    job: Job
    sreq: SchedRequest
    handle: TEHandle
    engine_req: Request
    submitted: float = field(default_factory=time.monotonic)


class ServingJobEngine:
    """Model-serving JE over a live FLOWSERVE fleet (DESIGN.md §9)."""

    decode_dominance: float = 4.0   # decode/prefill load ratio ⇒ grow 1P:Xd

    def __init__(self, bundle, params, topology: TopologySpec, *,
                 heatmap, prefill_lens, decode_ratios, predictor=None,
                 policy: str = "dist_sched",
                 ecfg: Optional[EngineConfig] = None,
                 dcfg: Optional[DistSchedConfig] = None,
                 scaler: Optional[FastScaler] = None,
                 trigger: Optional[LoadSpreadTrigger] = None,
                 drain_trigger: Optional[DrainTrigger] = None,
                 warm_pool: Optional[WarmPool] = None,
                 fleet_threads: int = 0,
                 fault_plan: Optional[FaultPlan] = None,
                 admission_limit: Optional[int] = None):
        if policy not in ("dist_sched", "round_robin"):
            raise ValueError(f"unknown policy {policy!r}")
        self.bundle = bundle
        self.params = params
        self.topology = topology
        base = ecfg if ecfg is not None else EngineConfig()
        # TopologySpec.tp and EngineConfig.tp describe the same thing;
        # whichever side was set wins, conflicting non-defaults are an error
        if base.tp != topology.tp:
            if base.tp == 1:
                base = replace(base, tp=topology.tp)
            elif topology.tp == 1:
                topology.tp = base.tp
            else:
                raise ValueError(f"conflicting tp: EngineConfig.tp={base.tp} "
                                 f"vs TopologySpec.tp={topology.tp}")
        self._base_ecfg = base
        self._offset_cursor = 0
        self._free_windows: List[int] = []      # released device windows
        self._window_of: Dict[str, int] = {}    # engine name -> owned window
        # window bookkeeping is driver-thread state, but concurrent fork
        # rounds (scale_to) allocate windows for in-flight bring-ups: the
        # lock + reserved set guarantee two forks are never handed the same
        # freed window before either registers
        self._window_lock = threading.Lock()
        self._reserved_windows: set = set()
        self.engines: List[FlowServe] = []
        self.policy = policy
        self.scaler = scaler
        self.trigger = trigger
        self.drain_trigger = drain_trigger
        self.warm_pool = warm_pool
        self.scale_events: List[Dict[str, Any]] = []
        self.resubmits: List[Dict[str, Any]] = []   # mid-prefill restarts
        self.lifecycle_log: List[Tuple[int, str, str]] = []
        # fault tolerance (DESIGN.md §11)
        self.fault_plan = fault_plan            # set BEFORE spawning: the
        #                                         initial fleet gets hooks
        self.admission_limit = admission_limit  # queued-per-serving-TE cap
        self.rejections: List[Dict[str, Any]] = []
        self._parked: List[Request] = []        # recovered, no survivor yet
        self._xfer_retry: Dict[str, Tuple[int, int]] = {}  # rid -> (n, due)
        self.xfer_retries = 0
        self.xfer_backoff_cap = 8               # max steps between retries
        self.steps = 0
        self.fleet_threads = fleet_threads
        self._fleet: Optional[FleetExecutor] = None
        self._fork_pool: Optional[FleetExecutor] = None  # scale_to rounds
        self._scale_seq = 0                     # te-scaleN naming

        handles: List[TEHandle] = []
        for gi, (n_p, n_d) in enumerate(topology.groups()):
            handle = TEHandle(f"te-pd{gi}", "pd_pair",
                              state=TEState.PROVISIONING)
            pes = [self._spawn(f"te-pd{gi}-p{j}" if n_p > 1
                               else f"te-pd{gi}-p", "prefill")
                   for j in range(n_p)]
            des = [self._spawn(f"te-pd{gi}-d{j}" if n_d > 1
                               else f"te-pd{gi}-d", "decode")
                   for j in range(n_d)]
            handle.engine, handle.decode_engine = pes[0], des[0]
            if n_p > 1:
                handle.prefill_engines = pes
            if n_d > 1:
                handle.decode_engines = des
            self._bring_up(handle)
            handles.append(handle)
        for i in range(topology.colo):
            handle = TEHandle(f"te-colo{i}", "colocated",
                              state=TEState.PROVISIONING)
            handle.engine = self._spawn(f"te-colo{i}", "colocated")
            self._bring_up(handle)
            handles.append(handle)
        # one M:N DistFlow peer group over the whole fleet (§4.6): PD groups
        # migrate KV, NPU-fork broadcasts weights, all on linked clocks
        for i, eng in enumerate(self.engines):
            eng.distflow.link_cluster(
                [p.distflow for p in self.engines[i + 1:]])

        if predictor is None and policy == "dist_sched":
            # PR-4 follow-up: predicted_decode comes from completed-request
            # traces (EMA per mix), not the sampling budget
            predictor = TraceEMAPredictor()
        self._handles = handles           # shared list: RR sees fleet churn
        self.scheduler = DistributedScheduler(
            handles, heatmap, prefill_lens, decode_ratios,
            predictor=predictor,
            cfg=dcfg if dcfg is not None else DistSchedConfig())
        self._rr = round_robin_scheduler(self._handles) \
            if policy == "round_robin" else None
        self.requests: Dict[str, _PlaneRequest] = {}
        self.jobs: Dict[str, Job] = {}
        self.completions: List[Completion] = []
        # per-group queue of (prefill TE, req_id) waiting on decode capacity
        self._migrate_pending: Dict[str, deque] = {
            h.te_id: deque() for h in handles if h.te_type == "pd_pair"}

    # ------------------------------------------------------------ fleet
    def _spawn(self, name: str, mode: str) -> FlowServe:
        off, owned = self._alloc_window()
        te = None
        try:
            ecfg = replace(self._base_ecfg, mode=mode, device_offset=off)
            te = FlowServe(self.bundle, self.params, ecfg, name=name)
            self._commit_window(name, off, owned)
        finally:
            if te is None:              # bring-up raised: free the window
                self._abort_window(off, owned)
        self._attach_faults(te)
        self.engines.append(te)
        return te

    def _attach_faults(self, te: FlowServe) -> None:
        """Wire the plane's fault plan into one engine (no-op without one).
        Every engine the plane creates — initial fleet, trigger forks,
        scale_to rounds — passes through here so injection covers the
        WHOLE fleet, not just the seed TEs."""
        if self.fault_plan is not None:
            self.fault_plan.attach(te)

    def _alloc_window(self) -> Tuple[int, bool]:
        """Disjoint per-TE device windows (DESIGN.md §7/§9) — width tp, or
        ONE device per TE at tp=1 so concurrent executors overlap device
        work instead of queueing on device 0. The free list fed by RELEASED
        TEs (scale-in) is consulted FIRST: a future fork reuses a drained
        TE's window before growing the fleet's device footprint. When the
        fleet outgrows the visible devices, later TEs fall back to window 0
        (simulated co-residence, not owned) rather than failing bring-up.
        Returns (offset, owned).

        An allocated window is RESERVED until ``_commit_window`` registers
        the TE that uses it: concurrent fork rounds allocate several
        windows before any of their bring-ups finish, and a release landing
        mid-round must not re-hand an offset that an in-flight fork already
        holds."""
        width = max(1, self.topology.tp)
        with self._window_lock:
            while self._free_windows:
                off = self._free_windows.pop()
                if off in self._reserved_windows:
                    continue
                self._reserved_windows.add(off)
                return off, True
            import jax
            if self._offset_cursor + width <= jax.device_count():
                off = self._offset_cursor
                self._offset_cursor += width
                self._reserved_windows.add(off)
                return off, True
            return 0, False

    def _commit_window(self, name: str, off: int, owned: bool) -> None:
        """Bind an allocated window to its now-registered TE (clears the
        in-flight reservation). Only an OWNED allocation holds a
        reservation — discarding unconditionally would clobber another
        in-flight fork's legitimate claim on offset 0 whenever a fallback
        (unowned) bring-up commits."""
        with self._window_lock:
            if owned:
                self._reserved_windows.discard(off)
                self._window_of[name] = off

    def _abort_window(self, off: int, owned: bool) -> None:
        """Release an in-flight window reservation whose bring-up FAILED
        (fork raised between alloc and commit). Without this the offset
        stays reserved forever and the fleet's device footprint shrinks
        permanently (§11 — the reserved-window leak)."""
        with self._window_lock:
            if owned:
                self._reserved_windows.discard(off)
                self._free_windows.append(off)

    def _bring_up(self, handle: TEHandle) -> None:
        """PROVISIONING → WARMING → SERVING (the §6 pipeline's TE-side
        states; bring-up here is synchronous, the transitions are what the
        rest of the plane keys on)."""
        self._log_state(handle, handle.transition(TEState.WARMING))
        self._log_state(handle, handle.transition(TEState.SERVING))

    def _log_state(self, handle: TEHandle, state: TEState) -> None:
        self.lifecycle_log.append((self.steps, handle.te_id, state.value))

    @property
    def handles(self) -> List[TEHandle]:
        return list(self._handles)

    def n_serving(self) -> int:
        return sum(1 for h in self._handles
                   if h.state is TEState.SERVING)

    def close(self) -> None:
        if self._fleet is not None:
            self._fleet.close()
            self._fleet = None
        if self._fork_pool is not None:
            self._fork_pool.close()
            self._fork_pool = None

    # ------------------------------------------------------------ intake
    def submit(self, tokens, sampling: Optional[SamplingParams] = None,
               predicted_decode: Optional[int] = None,
               request: Optional[UserRequest] = None) -> str:
        """request → job → task(s) → TE (Algorithm 1 or round-robin).

        Returns the request id; its ``Completion`` surfaces from ``step``
        once the decode finishes (on a group decode member or the colocated
        TE). ``predicted_decode`` defaults to the trace-trained EMA
        predictor's estimate (``TraceEMAPredictor``; the sampling budget
        only before any trace exists or under round-robin)."""
        sampling = sampling if sampling is not None else SamplingParams()
        if request is None:
            request = UserRequest(rtype=RequestType.CHAT,
                                  payload={"tokens": list(tokens),
                                           "max_new_tokens":
                                               sampling.max_new_tokens})
        self._check_admission(request)
        job = decompose(request)[0]
        job.status = Status.RUNNING
        self.jobs[job.job_id] = job
        if predicted_decode is None:
            pred = self.scheduler.predictor
            if self._rr is None and pred is not None \
                    and _predictor_trained(pred):
                predicted_decode = pred.predict_tokens(tokens)
            else:
                # no trace yet (or round-robin): the sampling budget is the
                # only honest estimate — a cold default would misroute
                # pd_aware and over-reserve load on the chosen TE
                predicted_decode = sampling.max_new_tokens
        sreq = SchedRequest(tokens=list(tokens),
                            predicted_decode=predicted_decode)
        if self._rr is not None:
            handle = self._rr(sreq)
        else:
            handle = self.scheduler.dist_sched(sreq)
            self.scheduler.commit(sreq, handle)
        if handle.te_type == "pd_pair":
            # Algorithm-1 M:N extension (§4.6): least-loaded prefill member
            pe = min(handle.prefill_members(), key=_engine_load)
            tp_ = job.spawn(TaskKind.PREFILL, tokens=list(tokens))
            tp_.te_id, tp_.status = pe.name, Status.RUNNING
            td = job.spawn(TaskKind.DECODE)
            td.te_id = None               # decode member picked at handoff
        else:
            pe = handle.engine
            tc = job.spawn(TaskKind.COLOCATED, tokens=list(tokens))
            tc.te_id, tc.status = pe.name, Status.RUNNING
        ereq = Request(prompt_tokens=list(tokens), sampling=sampling,
                       req_id=request.req_id)
        ereq.arrival = request.arrival      # TTFT from EXTERNAL arrival
        pe.add_request(ereq)
        self.requests[request.req_id] = _PlaneRequest(job, sreq, handle, ereq)
        return request.req_id

    def _check_admission(self, request: UserRequest) -> None:
        """Graceful degradation (DESIGN.md §11): with ``admission_limit``
        set, the plane's TOTAL queued-prefill backlog is bounded at
        ``limit × n_serving`` — capacity lost to failures shrinks the bound
        automatically (deficit-aware shedding). A breach REJECTS the
        request explicitly (``Status.REJECTED`` job + ``AdmissionRejected``)
        instead of building unbounded backlog while ``scale_to`` repairs
        the fleet."""
        if self.admission_limit is None:
            return
        serving = [h for h in self._handles if h.state is TEState.SERVING]
        cap = self.admission_limit * len(serving)
        queued = len(self._parked)
        for h in serving:
            for eng in self._members(h):
                queued += eng.load_metrics()["n_queued"]
        if serving and queued < cap:
            return
        job = decompose(request)[0]
        job.status = Status.REJECTED
        self.jobs[job.job_id] = job
        self.rejections.append({"req_id": request.req_id, "step": self.steps,
                                "queued": queued, "cap": cap,
                                "n_serving": len(serving)})
        raise AdmissionRejected(
            f"admission shed: {queued} queued >= cap {cap} "
            f"({len(serving)} serving TEs)", req_id=request.req_id)

    # ------------------------------------------------------------ drive
    def step(self) -> List[Completion]:
        """One JE iteration: step every live fleet unit — serially, or as
        submit/collect over the per-TE executors (``fleet_threads > 1``) so
        units overlap wall-clock work — then run the cross-unit phase on
        the driver thread: harvest completions, pump drains, feed the
        scale triggers."""
        units = [h for h in self._handles
                 if h.state in (TEState.SERVING, TEState.DRAINING)]
        out: List[Completion] = []
        failures: List[Tuple[str, BaseException]] = []
        if self.fleet_threads > 1 and len(units) > 1:
            if self._fleet is None:
                self._fleet = FleetExecutor(self.fleet_threads)
            for h in units:
                self._fleet.submit(h.te_id,
                                   (lambda hh=h: self._step_unit(hh)))
            done, failed = self._fleet.collect(len(units))
            for _, comps in done:
                out.extend(comps)
            failures.extend(failed)
        else:
            for h in units:
                try:
                    out.extend(self._step_unit(h))
                except Exception as exc:   # same quarantine as the threaded
                    failures.append((h.te_id, exc))   # path (§11)
        for comp in out:
            self._on_complete(comp)
        self.completions.extend(out)
        # containment AFTER harvesting: the surviving units' completions
        # this step are real — a failure never nukes them
        for te_id, exc in failures:
            self._on_unit_failure(te_id, exc)
        self._flush_parked()
        try:
            self._pump_drains()
        except TEFailureError as exc:
            # a source crashed mid-migration on the DRIVER thread (drain
            # pump) — same quarantine as a worker-thread failure; the
            # remaining drains pump next step
            h = next((hh for hh in self._handles
                      if any(e.name == exc.te
                             for e in self._members(hh))), None)
            if h is not None:
                self._on_unit_failure(h.te_id, exc)
        self._maybe_scale()
        self.steps += 1
        return out

    def _step_unit(self, handle: TEHandle) -> List[Completion]:
        """One unit's step: group-local work only (executor-safe — a unit's
        worker never touches another unit's engines). PD groups pump their
        internal handoff here: prefill members step, finished prefills
        migrate to the least-loaded decode member (capacity-gated
        backpressure), decode members step."""
        out: List[Completion] = []
        if handle.te_type == "pd_pair":
            for pe in handle.prefill_members():
                if pe.has_work():
                    pe.step()
            pending = self._migrate_pending[handle.te_id]
            for pe in handle.prefill_members():
                pending.extend((pe, rid) for rid in pe.pop_migratable())
            while pending:
                pe, rid = pending[0]
                if not self._try_migrate(pe, handle.pick_decode_member(),
                                         rid):
                    break                 # backpressure: retry next step
                pending.popleft()
            for de in handle.decode_members():
                if de.has_work():
                    out.extend(de.step())
        else:
            eng = handle.engine
            if eng.has_work():
                out.extend(eng.step())
        return out

    def has_work(self) -> bool:
        return bool(self.requests) or any(
            h.state is TEState.DRAINING for h in self._handles)

    def run_to_completion(self, max_steps: int = 20000) -> List[Completion]:
        out: List[Completion] = []
        for _ in range(max_steps):
            if not self.has_work():
                break
            out.extend(self.step())
        return out

    # ------------------------------------------------------------ PD pump
    def _try_migrate(self, pe: FlowServe, de: FlowServe, req_id: str) -> bool:
        """Hand one request's KV from ``pe`` to ``de`` over the §7 sharded
        path (PD handoff or drain migration). Returns False when the
        destination pool lacks pages for the KV run — the request stays
        queued on the source (backpressure) and the pump retries next
        step."""
        seq = pe._seqs.get(req_id)
        if seq is None:
            return True                   # released upstream; drop
        retry = self._xfer_retry.get(req_id)
        if retry is not None and self.steps < retry[1]:
            return False                  # backing off a transient fault
        if de.pool is not None:
            # cheap pre-gate; cached (reclaimable) pages count because the
            # import path evicts them coherently through the RTC
            free = de.pool.free_page_count() + len(de.pool.reclaimable())
            if len(seq.pages) > free:
                return False
        # import_request signals exhaustion (pages or slots) by raising
        # BEFORE committing destination state and before the source
        # releases — the request parks on the source side and retries
        from repro.engine.kv_cache import OutOfPagesError
        try:
            pe.migrate_out(req_id, de)
        except OutOfPagesError:
            return False
        except TransferFault:
            # transient wire failure: both endpoints already restored their
            # state (flowserve rolls back) — retry with capped exponential
            # backoff, measured in plane steps (§11)
            attempts = retry[0] + 1 if retry is not None else 1
            due = self.steps + min(self.xfer_backoff_cap,
                                   2 ** (attempts - 1))
            self._xfer_retry[req_id] = (attempts, due)
            self.xfer_retries += 1
            return False
        self._xfer_retry.pop(req_id, None)
        rec = self.requests.get(req_id)
        for task in (rec.job.tasks if rec is not None else ()):
            if task.kind == TaskKind.PREFILL:
                task.status = Status.DONE
            elif task.kind == TaskKind.DECODE:
                task.te_id, task.status = de.name, Status.RUNNING
            elif task.kind == TaskKind.COLOCATED:
                task.te_id = de.name      # drain migration re-homed it
        return True

    # ------------------------------------------------------------ harvest
    def _on_complete(self, comp: Completion) -> None:
        rec = self.requests.pop(comp.req_id, None)
        if rec is None:
            return
        for task in rec.job.tasks:
            task.status = Status.DONE
        rec.job.status = Status.DONE
        rec.job.result = comp
        if self._rr is None:
            # release the ACTUAL consumption, not the prediction — the
            # complete() drift fix only helps if callers pass actuals
            self.scheduler.complete(rec.sreq, rec.handle,
                                    actual_decode=len(comp.tokens))
            pred = self.scheduler.predictor
            if pred is not None and hasattr(pred, "observe"):
                # train the EMA predictor on the completed trace (§5.3.3)
                pred.observe(rec.sreq.tokens, len(comp.tokens))

    # ------------------------------------------------------------ failure
    def _handle_of_engine(self, eng: FlowServe) -> Optional[TEHandle]:
        for h in self._handles:
            if eng in self._members(h):
                return h
        return None

    def _on_unit_failure(self, te_id: str, exc: BaseException) -> None:
        """Detect → contain → recover for one failed fleet unit (§11).

        Containment: the unit walks FAILED → RELEASED, leaves routing
        (``admitting`` is False the moment it leaves SERVING; the handle
        is removed from both schedulers' views), and its device windows
        return to the free list for the repair fork to reuse.

        Recovery keeps the at-most-once invariant by building ONE restart
        set keyed on req_id, in this order: (1) survivors' in-flight KV
        imports whose SOURCE died are voided — those sequences restart;
        (2) requests resident on the dead unit restart UNLESS they are
        alive on a survivor (a mid-migration request whose import already
        landed continues on the destination — restarting it too would
        duplicate tokens); (3) only requests the plane still tracks
        restart (completed ones are done). Each restart re-enters the
        least-loaded surviving prefill-capable engine from the PROMPT via
        ``_resubmit`` (req_id + arrival preserved, restart counted); with
        no survivor it parks until capacity returns."""
        handle = next((h for h in self._handles if h.te_id == te_id), None)
        if handle is None:
            return                        # already quarantined
        self._log_state(handle, handle.transition(TEState.FAILED))
        dead = self._members(handle)
        dead_names = {e.name for e in dead}
        restart: Dict[str, Request] = {}
        for eng in self.engines:
            if eng in dead:
                continue
            for req in eng.void_pending_imports(dead_names):
                restart[req.req_id] = req
        alive = set()
        for eng in self.engines:
            if eng not in dead:
                alive.update(eng._requests.keys())
        for eng in dead:
            for rid, req in list(eng._requests.items()):
                if rid not in alive:
                    restart.setdefault(rid, req)
        restart = {rid: req for rid, req in restart.items()
                   if rid in self.requests}
        # quarantine: windows to the free list, engines/handle out of every
        # routing structure (a FAILED unit is replaced, not rebooted here —
        # scale_to repairs the fleet from survivors)
        self._log_state(handle, handle.transition(TEState.RELEASED))
        for eng in dead:
            with self._window_lock:
                off = self._window_of.pop(eng.name, None)
                if off is not None:
                    self._free_windows.append(off)
            if eng in self.engines:
                self.engines.remove(eng)
        self._handles.remove(handle)      # shared list: RR sees the removal
        self.scheduler.tes.pop(handle.te_id, None)
        self._migrate_pending.pop(handle.te_id, None)
        for rid in restart:
            self._xfer_retry.pop(rid, None)
        self.scale_events.append({"kind": "te_failure", "step": self.steps,
                                  "te_id": te_id, "error": repr(exc),
                                  "n_restarted": len(restart),
                                  "event": None})
        if self.drain_trigger is not None:
            self.drain_trigger.rearm()    # capacity loss: never keep draining
        if self.trigger is not None:
            # the lost capacity must be able to re-fire scale-out
            # immediately, whatever the trigger's re-arm state was
            self.trigger.armed = True
            self.trigger.breach_steps = 0
        for rid, req in restart.items():
            dst = self._resubmit_destination(exclude=handle)
            if dst is None:
                self._parked.append(req)
                continue
            self._resubmit(req, dst, src=te_id, reason="te_failure")

    def _flush_parked(self) -> None:
        """Re-home requests whose failure-time restart found no surviving
        admitting engine (total capacity loss) once repair restores one."""
        if not self._parked:
            return
        parked, self._parked = self._parked, []
        for req in parked:
            dst = self._resubmit_destination(exclude=None)
            if dst is None:
                self._parked.append(req)
            else:
                self._resubmit(req, dst, src="parked", reason="te_failure")

    def restart_counts(self) -> Dict[str, int]:
        """Per-request restart tally over the whole run (at-most-once
        accounting input for the fault bench)."""
        counts: Dict[str, int] = {}
        for r in self.resubmits:
            counts[r["req_id"]] = counts.get(r["req_id"], 0) + 1
        return counts

    # ------------------------------------------------------------ scale-in
    def drain(self, te_id: str) -> TEHandle:
        """Begin scale-in of one TE (DESIGN.md §9): SERVING → DRAINING.
        Admissions stop immediately (Algorithm 1 and RR both skip
        non-admitting handles); each subsequent ``step`` migrates its
        movable decodes out over the §7 path and lets the rest finish,
        then releases the TE. Illegal states raise ``LifecycleError``."""
        handle = next((h for h in self._handles if h.te_id == te_id), None)
        if handle is None:
            raise KeyError(f"unknown TE {te_id!r}")
        self._log_state(handle, handle.transition(TEState.DRAINING))
        self.scale_events.append({"kind": "drain", "step": self.steps,
                                  "te_id": te_id, "event": None})
        return handle

    def cancel_drain(self, te_id: str) -> TEHandle:
        """Drain-CANCEL (DESIGN.md §10): DRAINING → SERVING on a load
        resurgence — the capacity being drained is needed after all, so
        admissions resume instead of releasing the window. The state
        machine already permits the transition; this is what drives it."""
        handle = next((h for h in self._handles if h.te_id == te_id), None)
        if handle is None:
            raise KeyError(f"unknown TE {te_id!r}")
        self._log_state(handle, handle.transition(TEState.SERVING))
        self.scale_events.append({"kind": "drain_cancel", "step": self.steps,
                                  "te_id": te_id, "event": None})
        if self.drain_trigger is not None:
            self.drain_trigger.rearm()    # the in-flight drain is over
        return handle

    def _pump_drains(self) -> None:
        """Driver-thread drain progress. First the resurgence check: if the
        still-serving TEs' mean load shot past the drain trigger's
        resurgence watermark, every in-flight drain is CANCELLED
        (DRAINING → SERVING) instead of pumped. Otherwise each draining
        TE's mid-PREFILL work is re-submitted to a prefill-capable
        destination (token-level restart — finishing prefill on a TE
        that's leaving just delays the release), its movable decodes
        migrate to the least-loaded admitting destination
        (capacity-gated), and the TE is released once genuinely empty."""
        draining = [h for h in self._handles if h.state is TEState.DRAINING]
        if not draining:
            return
        if self.drain_trigger is not None:
            serving = [h for h in self._handles
                       if h.state is TEState.SERVING]
            if serving and self.drain_trigger.resurgent(
                    [h.refresh() for h in serving]):
                for handle in draining:
                    self.cancel_drain(handle.te_id)
                return
        for handle in draining:
            dst = self._drain_destination(exclude=handle)
            if dst is not None:
                resub_dst = self._resubmit_destination(exclude=handle)
                if resub_dst is not None:
                    for eng in self._members(handle):
                        for req in eng.cancel_queued():
                            self._resubmit(req, resub_dst, src=eng.name)
                for eng in self._decode_side(handle):
                    for rid in eng.migratable_running():
                        if not self._try_migrate(eng, dst, rid):
                            break
            if not any(e.has_work() for e in self._members(handle)) \
                    and not self._migrate_pending.get(handle.te_id):
                self._release(handle)

    def _resubmit_destination(self, exclude: TEHandle) -> Optional[FlowServe]:
        """Least-loaded admitting PREFILL-capable engine outside
        ``exclude`` (a decode-mode member can't restart a prompt)."""
        best, best_load = None, None
        for h in self._handles:
            if h is exclude or not h.admitting:
                continue
            if h.te_type == "pd_pair":
                eng = min(h.prefill_members(), key=_engine_load)
            else:
                eng = h.engine
            if eng is None:
                continue
            load = _engine_load(eng)
            if best_load is None or load < best_load:
                best, best_load = eng, load
        return best

    def _resubmit(self, req: Request, dst: FlowServe, src: str,
                  reason: str = "drain") -> None:
        """Token-level restart of a mid-PREFILL (or failure-recovered)
        request on ``dst``: the original ``Request`` (req_id + external
        arrival preserved, so TTFT spans the restart) re-enters the
        destination's scheduler from the prompt. Recorded in ``resubmits``,
        NOT ``scale_events`` — it's request routing, not fleet shape."""
        dst.add_request(req)
        rec = self.requests.get(req.req_id)
        if rec is not None:
            for task in rec.job.tasks:
                if task.kind in (TaskKind.PREFILL, TaskKind.COLOCATED):
                    task.te_id, task.status = dst.name, Status.RUNNING
        self.resubmits.append({"req_id": req.req_id, "from": src,
                               "to": dst.name, "step": self.steps,
                               "reason": reason})

    def _members(self, handle: TEHandle) -> List[FlowServe]:
        if handle.te_type == "pd_pair":
            return [*handle.prefill_members(), *handle.decode_members()]
        return [handle.engine]

    def _decode_side(self, handle: TEHandle) -> List[FlowServe]:
        return (handle.decode_members() if handle.te_type == "pd_pair"
                else [handle.engine])

    def _drain_destination(self, exclude: TEHandle) -> Optional[FlowServe]:
        """Least-loaded admitting decode-capable engine outside ``exclude``."""
        best, best_load = None, None
        for h in self._handles:
            if h is exclude or not h.admitting:
                continue
            eng = (h.pick_decode_member() if h.te_type == "pd_pair"
                   else h.engine)
            if eng is None:
                continue
            load = _engine_load(eng)
            if best_load is None or load < best_load:
                best, best_load = eng, load
        return best

    def _release(self, handle: TEHandle) -> None:
        """DRAINING → RELEASED: drop the TE from the fleet and return its
        device window to the free list (the next fork reuses it). With a
        ``WarmPool`` attached, the TE's device-resident params drain back
        to host DRAM on the way out — the RELEASED → warm leg of the
        cold-start ladder (DESIGN.md §10) — so a later scale-out comes up
        from warm instead of cold."""
        self._log_state(handle, handle.transition(TEState.RELEASED))
        asset = self._asset_name()
        for eng in self._members(handle):
            if self.warm_pool is not None:
                host = eng.release_params(
                    to_host=not self.warm_pool.hit(asset))
                if host is not None:
                    self.warm_pool.put(asset, host, host_copy=False)
            with self._window_lock:
                off = self._window_of.pop(eng.name, None)
                if off is not None:
                    self._free_windows.append(off)
            if eng in self.engines:
                self.engines.remove(eng)
        self._handles.remove(handle)      # shared list: RR sees the removal
        self.scheduler.tes.pop(handle.te_id, None)
        self._migrate_pending.pop(handle.te_id, None)
        self.scale_events.append({"kind": "release", "step": self.steps,
                                  "te_id": handle.te_id, "event": None})
        if self.drain_trigger is not None:
            self.drain_trigger.rearm()    # the in-flight drain completed

    # ------------------------------------------------------------ scaling
    def _maybe_scale(self) -> None:
        if self.trigger is None and self.drain_trigger is None:
            return
        # mutual exclusion (per TE and per fleet): while ANY TE drains,
        # neither trigger is fed — a draining TE's load collapsing toward
        # zero looks exactly like a spread breach, and forking while
        # shrinking (or vice versa) would thrash. The spread trigger also
        # must not RE-ARM off the drain's transient profile. (Checked
        # before refreshing: refresh() locks every engine.)
        if any(h.state is TEState.DRAINING for h in self._handles):
            return
        live = [h for h in self._handles if h.state is TEState.SERVING]
        loads = [h.refresh() for h in live]
        deficit = self.trigger.observe(loads) if self.trigger is not None \
            else 0
        if deficit > 1:
            # capacity deficit (te_capacity set): one fire requests the
            # whole fork TREE instead of one fork per re-arm cycle
            self.scale_to(self.n_serving() + deficit)
            return
        if deficit:
            self._scale_out()
            return
        if self.drain_trigger is not None:
            if self.trigger is not None and self.trigger.breach_steps > 0:
                return                    # a fork may be imminent: hold
            if self.drain_trigger.observe(loads, self.n_serving()):
                self._start_drain()

    def _start_drain(self) -> None:
        """Pick the scale-in victim: the least-loaded admitting colocated
        TE (PD group members are structural — their decode side shrinks
        only when a grown member empties, future work). A fired trigger
        with NO drainable candidate re-arms immediately — otherwise a
        pd-only fleet would disarm it forever on the first idle spell."""
        cands = [h for h in self._handles
                 if h.te_type == "colocated" and h.admitting]
        if len(cands) < 1 or self.n_serving() <= 1:
            if self.drain_trigger is not None:
                self.drain_trigger.rearm()
            return
        victim = min(cands, key=lambda h: h.load)
        self.drain(victim.te_id)

    fork_max_attempts: int = 4          # per-fork retry budget (§11)

    def _scale_out(self) -> None:
        """Spread breach: NPU-fork capacity from a live engine (§6.3).
        Decode-dominated pressure with a PD group present grows that
        group's decode side (M:N, §4.6); anything else forks a whole
        colocated TE. FastScaler prices the 5-step bring-up pipeline
        around the same fork.

        Fault handling (§11): a transient ``ForkFault`` retries with
        capped exponential backoff, rotating to an ALTERNATIVE source; a
        source that dies mid-fork (``TEFailureError``) is quarantined via
        ``_on_unit_failure`` and the retry continues from a survivor. The
        window reservation is released in a ``finally`` whenever no TE
        registers — a failed fork must not leak the offset."""
        live = [h for h in self._handles if h.admitting]
        pd_handles = [h for h in live if h.te_type == "pd_pair"]
        total_p = sum(h.prefill_load for h in live)
        total_d = sum(h.decode_load for h in live)
        grow_group = (pd_handles
                      and total_d > self.decode_dominance * max(1.0, total_p))
        if grow_group:
            group = max(pd_handles, key=lambda h: h.decode_load)
            candidates = sorted(group.decode_members(), key=_engine_load)
            name = f"{group.te_id}-d{len(group.decode_members())}"
            mode = "decode"
        else:
            group = None
            candidates = sorted((h.decode_engine or h.engine for h in live),
                                key=_engine_load)
            name = f"te-scale{self._scale_seq}"
            mode = "colocated"
        off, owned = self._alloc_window()
        te = src_engine = None
        try:
            ecfg = replace(self._base_ecfg, mode=mode, device_offset=off)
            for attempt in range(self.fork_max_attempts):
                if not candidates:
                    break
                src_engine = candidates[attempt % len(candidates)]
                try:
                    te = FlowServe.fork_from(src_engine, ecfg, name=name)
                    break
                except ForkFault:
                    time.sleep(backoff_s(attempt))
                except TEFailureError as exc:
                    src_handle = self._handle_of_engine(src_engine)
                    dead = set(self._members(src_handle)) \
                        if src_handle is not None else {src_engine}
                    if src_handle is not None:
                        self._on_unit_failure(src_handle.te_id, exc)
                    candidates = [c for c in candidates
                                  if c not in dead and c.fork_ready]
                    if group is not None and not candidates:
                        break   # the group's own decode side is gone
            if te is not None:
                self._commit_window(name, off, owned)
        finally:
            if te is None:
                self._abort_window(off, owned)
        if te is None:
            self.scale_events.append({"kind": "fork_failed",
                                      "step": self.steps, "te_id": name,
                                      "event": None})
            if self.trigger is not None:
                self.trigger.armed = True   # deficit persists: re-fire
            return
        self._attach_faults(te)
        # the new TE walks the same lifecycle as the initial fleet
        handle = (group if group is not None else
                  TEHandle(name, "colocated", state=TEState.PROVISIONING))
        if group is None:
            self._scale_seq += 1
        for eng in self.engines:
            eng.distflow.link_cluster([te.distflow])
        self.engines.append(te)
        event = None
        if self.scaler is not None:
            from repro.core.scaling import LoadResult
            from repro.engine.distflow import _nbytes
            asset = ModelAsset(name=getattr(self.bundle.cfg, "name", "model"),
                               n_bytes=_nbytes(self.params),
                               tp=max(1, self.topology.tp))
            # fork_from already moved the weights and charged DistFlow;
            # hand its transfer to the pipeline as the TE-Load step
            xfer = src_engine.distflow.log[-1]
            event = self.scaler.scale_one(
                asset, optimized=True,
                preloaded=LoadResult("npu_fork_ici", xfer.sim_seconds,
                                     xfer.n_bytes))
        if group is not None:
            group.grow_decode(te)
            self.scale_events.append({"kind": "grow_decode",
                                      "step": self.steps, "te_id": name,
                                      "group": group.te_id,
                                      "source": src_engine.name,
                                      "event": event})
            return
        handle.engine = te
        self._bring_up(handle)
        self._handles.append(handle)
        self.scheduler.tes[name] = handle
        self.scale_events.append({"kind": "fork", "step": self.steps,
                                  "te_id": name, "source": src_engine.name,
                                  "event": event})

    # ------------------------------------------------------------ mass scale
    def _asset_name(self) -> str:
        return getattr(self.bundle.cfg, "name", "model")

    def _fork_sources(self) -> List[FlowServe]:
        """Every SERVING engine whose params are still device-resident —
        the fork-source pool a scale-out round fans out from."""
        out: List[FlowServe] = []
        for h in self._handles:
            if h.state is not TEState.SERVING:
                continue
            out.extend(e for e in self._members(h) if e.fork_ready)
        return out

    def _fork_executor(self) -> FleetExecutor:
        if self._fork_pool is None:
            self._fork_pool = FleetExecutor(8)
        return self._fork_pool

    def scale_to(self, n: int, fan_out: bool = True,
                 warmup: bool = False,
                 pace: Optional[ModelAsset] = None) -> Dict[str, Any]:
        """Mass scale-out to ``n`` SERVING TEs through the cold-start
        ladder (DESIGN.md §10), in O(log N) FORK ROUNDS:

        * round k forks one new TE from EVERY fork-ready SERVING engine —
          each TE that reached SERVING in round k is a source in round
          k+1, so the fleet doubles per round (λScale's multicast tree);
          forks within a round run concurrently on executor threads
          (``fork_from`` is executor-safe via the per-source RLock);
        * when the round's deficit exceeds the source pool, the remainder
          comes up from the DRAM-warm tier (``WarmPool``) — one host
          entry serves any number of concurrent ``device_put``s;
        * with neither a source nor a warm entry, bring-up is cold init.

        ``fan_out=False`` degrades to serial one-at-a-time forking (the
        bench baseline: identical registration path and final placement,
        N-1 rounds instead of ceil(log2 N)). ``warmup`` precompiles a
        small decode grid on each new TE before it's declared SERVING.
        ``pace`` holds every bring-up job to the modeled full-size tier
        cost of that asset (``scaling.tier_seconds``): the CPU sim's
        smoke-scale copies finish in microseconds, so without pacing the
        measured wall is pure python overhead — with it, each job's wall
        is the larger of its real device work and the priced transfer,
        the same modeled-cost idiom as ``FastScaler``. Returns the
        executed plan (per-round TEs/sources/tiers + wall)."""
        plan: Dict[str, Any] = {
            "target": n, "start_serving": self.n_serving(),
            "rounds": [], "tiers": {"fork": 0, "warm": 0, "cold": 0}}
        t_all = time.monotonic()
        asset = self._asset_name()
        # tag asserts the entry's model-asset identity (§11): a mispointed
        # pool entry fails loudly here, not as a TE serving wrong weights
        warm_params = self.warm_pool.get(asset, tag=asset) \
            if self.warm_pool is not None else None
        stalls = 0                      # consecutive zero-progress rounds
        while self.n_serving() < n:
            deficit = n - self.n_serving()
            sources = self._fork_sources()
            n_fork = min(deficit, len(sources))
            n_rest = deficit - n_fork if warm_params is not None \
                or not sources else 0
            if not sources:
                n_rest = deficit            # warm or cold: no source needed
            if not fan_out:
                n_fork = min(1, n_fork)
                n_rest = 0 if n_fork else min(1, n_rest)
            jobs: List[Tuple[str, int, bool, str, Optional[str], Any]] = []
            for j in range(n_fork + n_rest):
                off, owned = self._alloc_window()
                name = f"te-scale{self._scale_seq}"
                self._scale_seq += 1
                ecfg = replace(self._base_ecfg, mode="colocated",
                               device_offset=off)
                if j < n_fork:
                    tier, src = "fork", sources[j]
                elif warm_params is not None:
                    tier, src = "warm", None
                else:
                    tier, src = "cold", None
                pace_s = tier_seconds(pace, tier) if pace is not None else 0.0
                jobs.append((name, off, owned, tier,
                             src.name if src is not None else None,
                             self._job_bring_up(name, ecfg, tier, src,
                                                warm_params, warmup,
                                                pace_s=pace_s)))
            t_round = time.monotonic()
            failed: Dict[str, BaseException] = {}
            if len(jobs) > 1:
                pool = self._fork_executor()
                for name, _, _, _, _, fn in jobs:
                    pool.submit(name, fn)
                done_list, failed_list = pool.collect(len(jobs))
                done = dict(done_list)
                failed = dict(failed_list)
            else:
                done = {}
                for name, _, _, _, _, fn in jobs:
                    try:
                        done[name] = fn()
                    except Exception as exc:
                        failed[name] = exc
            round_tes = []
            for name, off, owned, tier, src_name, _ in jobs:
                if name not in done:
                    # bring-up failed (transient ForkFault retries next
                    # round from the recomputed deficit): free the window
                    # reservation, and if the SOURCE died mid-fork,
                    # quarantine it before the next round forks from it
                    self._abort_window(off, owned)
                    exc = failed.get(name)
                    dead_te = getattr(exc, "te", None)
                    if dead_te is not None:
                        src_handle = next(
                            (h for h in self._handles
                             if any(e.name == dead_te
                                    for e in self._members(h))), None)
                        if src_handle is not None:
                            self._on_unit_failure(src_handle.te_id, exc)
                    continue
                te, fork_s = done[name]
                self._register_scaled(te, off, owned, tier, src_name,
                                      fork_s, len(plan["rounds"]))
                plan["tiers"][tier] += 1
                round_tes.append(name)
            plan["rounds"].append({
                "round": len(plan["rounds"]), "tes": round_tes,
                "failed": sorted(failed),
                "sources": [j[4] for j in jobs if j[4] is not None],
                "wall_s": time.monotonic() - t_round})
            if round_tes:
                stalls = 0
            else:
                stalls += 1
                if stalls >= 4:
                    raise RuntimeError(
                        f"scale_to({n}) stalled: {stalls} consecutive "
                        f"rounds with no successful bring-up "
                        f"(last errors: {sorted(map(repr, failed.values()))})")
                time.sleep(backoff_s(stalls))
        plan["wall_s"] = time.monotonic() - t_all
        plan["n_serving"] = self.n_serving()
        return plan

    def _job_bring_up(self, name: str, ecfg: EngineConfig, tier: str,
                      src: Optional[FlowServe], warm_params, warmup: bool,
                      pace_s: float = 0.0):
        """One bring-up closure, safe to run on an executor thread: builds
        the TE through its tier's path and (optionally) precompiles a
        small decode grid. ``pace_s`` > 0 pads the job to the modeled
        full-size tier cost (a sleep releases the GIL, so padded jobs in
        one round overlap exactly like real transfers on independent
        links would). Registration stays on the driver thread."""
        def job():
            t0 = time.monotonic()
            if tier == "fork":
                te = FlowServe.fork_from(src, ecfg, name=name)
            elif tier == "warm":
                te = FlowServe.from_warm(self.bundle, warm_params, ecfg,
                                         name=name)
            else:
                te = FlowServe(self.bundle, self.params, ecfg, name=name)
            jax.block_until_ready(te.runner.params)
            if warmup:
                te.warmup_decode(max_pages=2, horizons=[1])
            left = pace_s - (time.monotonic() - t0)
            if left > 0:
                time.sleep(left)
            return te, time.monotonic() - t0
        return job

    def _register_scaled(self, te: FlowServe, off: int, owned: bool,
                         tier: str, src_name: Optional[str], fork_s: float,
                         rnd: int) -> None:
        """Driver-thread registration of one scaled-out TE: commit its
        window, link it into the fleet's DistFlow peer group, walk the
        lifecycle to SERVING, and expose it to Algorithm 1."""
        self._commit_window(te.name, off, owned)
        self._attach_faults(te)
        for eng in self.engines:
            eng.distflow.link_cluster([te.distflow])
        self.engines.append(te)
        event = None
        if self.scaler is not None:
            from repro.core.scaling import LoadResult
            from repro.engine.distflow import _nbytes
            asset = ModelAsset(name=self._asset_name(),
                               n_bytes=_nbytes(self.params),
                               tp=max(1, self.topology.tp))
            # the bring-up already happened: hand its measured wall to the
            # pipeline as the TE-Load step (tiered pricing, no double
            # charge on the transfer fabric)
            path = {"fork": "npu_fork_ici", "warm": "warm_pool",
                    "cold": "cold_init"}[tier]
            event = self.scaler.scale_one(
                asset, optimized=True,
                preloaded=LoadResult(path, fork_s, asset.n_bytes))
        handle = TEHandle(te.name, "colocated", state=TEState.PROVISIONING)
        handle.engine = te
        self._bring_up(handle)
        self._handles.append(handle)
        self.scheduler.tes[te.name] = handle
        self.scale_events.append({"kind": "fork", "step": self.steps,
                                  "te_id": te.name, "source": src_name,
                                  "tier": tier, "round": rnd,
                                  "event": event})

    # ------------------------------------------------------------ stats
    def fleet_metrics(self) -> Dict[str, Dict[str, float]]:
        """Per-handle live load snapshot (refreshes every handle)."""
        out = {}
        for handle in self._handles:
            handle.refresh()
            out[handle.te_id] = {"load": handle.load,
                                 "n_running": handle.n_running,
                                 "type": handle.te_type,
                                 "state": handle.state.value,
                                 "n_prefill": len(handle.prefill_members())
                                 if handle.te_type == "pd_pair" else 0,
                                 "n_decode": len(handle.decode_members())
                                 if handle.te_type == "pd_pair" else 0}
        return out
