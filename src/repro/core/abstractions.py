"""The request-job-task serverless abstraction (§3).

A *request* is an external trigger (HTTP call). A *job* of matching type
handles it (chat → serving job; fine-tune → preprocess/train/eval jobs).
A *task* is a fine-grained operation within a job (prefill task, decode
task, training shard). JEs decompose requests into jobs and tasks; TEs
execute tasks; the cluster manager owns health and scaling.
"""
from __future__ import annotations

import enum
import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

_ids = itertools.count()


def _mkid(prefix: str) -> str:
    return f"{prefix}-{next(_ids)}"


class RequestType(str, enum.Enum):
    CHAT = "chat"
    BATCH_INFERENCE = "batch_inference"
    FINE_TUNE = "fine_tune"
    EMBEDDING = "embedding"


class JobKind(str, enum.Enum):
    SERVING = "serving"
    PREPROCESS = "preprocess"
    TRAINING = "training"
    EVALUATION = "evaluation"


class TaskKind(str, enum.Enum):
    PREFILL = "prefill"
    DECODE = "decode"
    COLOCATED = "colocated"        # single task on a PD-colocated TE
    TRAIN_SHARD = "train_shard"
    EVAL_SHARD = "eval_shard"
    PREPROCESS_SHARD = "preprocess_shard"


class Status(str, enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"
    REJECTED = "rejected"          # shed by admission control (§11)


@dataclass
class UserRequest:
    rtype: RequestType
    payload: Dict[str, Any]
    req_id: str = field(default_factory=lambda: _mkid("req"))
    arrival: float = field(default_factory=time.monotonic)
    model: str = "default"
    slo_ttft: Optional[float] = None
    slo_tpot: Optional[float] = None


@dataclass
class Task:
    kind: TaskKind
    job_id: str
    payload: Dict[str, Any] = field(default_factory=dict)
    task_id: str = field(default_factory=lambda: _mkid("task"))
    status: Status = Status.PENDING
    te_id: Optional[str] = None
    result: Any = None


@dataclass
class Job:
    kind: JobKind
    request: UserRequest
    job_id: str = field(default_factory=lambda: _mkid("job"))
    tasks: List[Task] = field(default_factory=list)
    status: Status = Status.PENDING
    result: Any = None

    def spawn(self, kind: TaskKind, **payload) -> Task:
        t = Task(kind=kind, job_id=self.job_id, payload=payload)
        self.tasks.append(t)
        return t

    def done(self) -> bool:
        return all(t.status == Status.DONE for t in self.tasks)


def decompose(request: UserRequest) -> List[Job]:
    """Request → jobs, per §3: a chat request triggers one serving job; a
    fine-tune request triggers preprocess + training + evaluation jobs."""
    if request.rtype in (RequestType.CHAT, RequestType.BATCH_INFERENCE,
                         RequestType.EMBEDDING):
        return [Job(JobKind.SERVING, request)]
    if request.rtype == RequestType.FINE_TUNE:
        return [Job(JobKind.PREPROCESS, request),
                Job(JobKind.TRAINING, request),
                Job(JobKind.EVALUATION, request)]
    raise ValueError(request.rtype)
