"""Elastic fleet runtime (DESIGN.md §9): TE lifecycle + per-TE executors.

Two pieces the serving plane composes:

* **TE lifecycle state machine** — every fleet member walks
  ``PROVISIONING → WARMING → SERVING ⇄ DRAINING → RELEASED``. Transitions
  are validated (`advance`); anything else raises ``LifecycleError``. Only
  SERVING TEs admit new placements; a DRAINING TE keeps stepping until its
  in-flight requests complete or migrate out (§7 sharded path), then its
  device window is RELEASED for reuse by a future fork. DRAINING → SERVING
  models drain-cancel on a load resurgence.

* **FleetExecutor** — thread-per-TE-unit execution so engines genuinely
  overlap wall-clock work. A *unit* is what the old serial loop iterated:
  one PD group (its prefill members, the intra-group handoff pump, its
  decode members) or one colocated TE — so a worker never touches another
  unit's engines and the per-unit event stream stays ordered. The JE
  submits one step event per unit and collects result events from a single
  barrier-free completion queue (results surface in finish order, not
  submit order); cross-unit actions (placement, drain migration, scaling)
  stay on the driver thread between steps. jit dispatches release the GIL,
  which is where the overlap comes from on CPU and the whole point on real
  accelerators.
"""
from __future__ import annotations

import enum
import queue
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple


class TEState(str, enum.Enum):
    PROVISIONING = "provisioning"   # pod/devices allocated, engine building
    WARMING = "warming"             # weights resident, jit warmup running
    SERVING = "serving"             # admitting + executing
    DRAINING = "draining"           # admissions stopped; emptying (§9 scale-in)
    FAILED = "failed"               # crashed; quarantined, work recovering
    RELEASED = "released"           # device window freed; terminal


class LifecycleError(RuntimeError):
    """Raised on an illegal TE state transition."""


_LEGAL: Dict[TEState, Tuple[TEState, ...]] = {
    TEState.PROVISIONING: (TEState.WARMING, TEState.RELEASED),
    TEState.WARMING: (TEState.SERVING, TEState.FAILED),
    TEState.SERVING: (TEState.DRAINING, TEState.FAILED),
    TEState.DRAINING: (TEState.SERVING, TEState.RELEASED, TEState.FAILED),
    # FAILED -> WARMING is reboot-in-place (§7); FAILED -> RELEASED is
    # replace (quarantine frees the device window for a fresh fork)
    TEState.FAILED: (TEState.WARMING, TEState.RELEASED),
    TEState.RELEASED: (),
}


def advance(current: TEState, new: TEState) -> TEState:
    """Validate one lifecycle transition; returns ``new`` or raises."""
    if new not in _LEGAL[current]:
        raise LifecycleError(f"illegal TE transition {current.value} -> "
                             f"{new.value} (legal: "
                             f"{[s.value for s in _LEGAL[current]] or 'none'})")
    return new


_STOP = object()


class _Worker:
    """One daemon thread draining its own inbox into the shared results
    queue. Units are PINNED to workers, so one unit's events always execute
    in order on one thread (engines keep thread affinity)."""

    def __init__(self, name: str, results: "queue.SimpleQueue"):
        self.inbox: queue.SimpleQueue = queue.SimpleQueue()
        self._results = results
        self.thread = threading.Thread(target=self._run, name=name,
                                       daemon=True)
        self.thread.start()

    def _run(self) -> None:
        while True:
            item = self.inbox.get()
            if item is _STOP:
                return
            tag, fn = item
            try:
                self._results.put((tag, fn(), None))
            except BaseException as exc:  # surfaced by collect()
                self._results.put((tag, None, exc))


class FleetExecutor:
    """Submit/collect executor over at most ``n_threads`` pinned workers.

    ``submit(unit_id, fn)`` enqueues ``fn`` on the worker the unit is
    pinned to (units are assigned round-robin on first submit, so a fleet
    larger than the thread budget shares workers without losing per-unit
    ordering). ``collect(n)`` pops ``n`` completion events in FINISH order
    — there is no inter-unit barrier inside the executor; the caller
    decides how many events its step owes."""

    def __init__(self, n_threads: int):
        if n_threads < 1:
            raise ValueError(f"n_threads must be >= 1, got {n_threads}")
        self.n_threads = n_threads
        self._results: queue.SimpleQueue = queue.SimpleQueue()
        self._workers: List[_Worker] = []
        self._pin: Dict[Any, _Worker] = {}
        self._closed = False

    def _worker_for(self, unit_id: Any) -> _Worker:
        w = self._pin.get(unit_id)
        if w is None:
            if len(self._workers) < self.n_threads:
                w = _Worker(f"fleet-worker-{len(self._workers)}",
                            self._results)
                self._workers.append(w)
            else:
                w = self._workers[len(self._pin) % self.n_threads]
            self._pin[unit_id] = w
        return w

    def submit(self, unit_id: Any, fn: Callable[[], Any]) -> None:
        if self._closed:
            raise RuntimeError("executor closed")
        self._worker_for(unit_id).inbox.put((unit_id, fn))

    def collect(self, n: int) -> Tuple[List[Tuple[Any, Any]],
                                       List[Tuple[Any, BaseException]]]:
        """Block until ``n`` events complete; returns ``(done, failed)``
        where ``done`` is [(unit_id, result)] for units that finished and
        ``failed`` is [(unit_id, exc)] for units whose fn raised. A failing
        unit is QUARANTINED by the caller — its failure never aborts the
        other units' step and collect itself never raises (DESIGN.md §11).
        All ``n`` events are always drained so none is left orphaned."""
        done: List[Tuple[Any, Any]] = []
        failed: List[Tuple[Any, BaseException]] = []
        for _ in range(n):
            tag, result, exc = self._results.get()
            if exc is not None:
                failed.append((tag, exc))
            else:
                done.append((tag, result))
        return done, failed

    def close(self) -> None:
        self._closed = True
        for w in self._workers:
            w.inbox.put(_STOP)
        for w in self._workers:
            w.thread.join(timeout=5.0)
