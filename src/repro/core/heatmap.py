"""PD-disaggregated vs PD-colocated heatmap (§5.3.1, Figure 6).

For each (prefill_len, decode_ratio, rps) cell we price a batch of
identical requests on (a) a PD-disaggregated 1P+1D pair and (b) two
PD-colocated TEs with chunked prefill, and record
    value = JCT_colocated / JCT_disaggregated - 1
(positive ⇒ disaggregation wins, matching the paper's convention).
The combined heatmap (element-wise sum over RPS, §5.3.2) feeds
``select_tes_PD_heatmap``. The same code can also be driven by measured
timings from the live CPU engine (benchmarks/bench_fig6_heatmap.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.perf_model import TECostModel, TEHardware

PREFILL_LENS = [256, 512, 1024, 2048, 4096, 8192]
DECODE_RATIOS = [0.05, 0.1, 0.25, 0.5, 1.0, 2.0]
RPS_GRID = [0.2, 0.4, 0.6, 0.8, 1.0, 1.2]


@dataclass
class HeatmapStudy:
    cfg: ModelConfig
    hw: TEHardware = field(default_factory=TEHardware)
    prefill_lens: List[int] = field(default_factory=lambda: list(PREFILL_LENS))
    decode_ratios: List[float] = field(default_factory=lambda: list(DECODE_RATIOS))
    rps_grid: List[float] = field(default_factory=lambda: list(RPS_GRID))

    def __post_init__(self):
        self.cost = TECostModel(self.cfg, self.hw)

    # ---------------------------------------------------------------- cells
    def jct_disaggregated(self, p_len: int, d_len: int, rps: float) -> float:
        """1 prefill TE + 1 decode TE. Prefill pipelines with decode; under
        load the slower stage saturates (M/D/1-flavored waiting)."""
        t_p = self.cost.prefill_time(p_len)
        batch = max(1, min(16, int(rps * d_len * self.cost.decode_step_time(8, p_len) * 8)))
        t_d = self.cost.decode_time(d_len, batch, p_len)
        # queueing: arrival每 1/rps; service at the bottleneck stage
        util = min(0.95, rps * max(t_p, t_d / max(batch, 1)))
        wait = (util / max(1e-9, (1 - util))) * max(t_p, t_d / max(batch, 1)) * 0.5
        # KV transfer between TEs (by-req): overlapped with decode ramp
        kv_bytes = self.cost.kv_bytes_per_token * p_len
        t_xfer = kv_bytes / 50e9
        return t_p + t_xfer + t_d + wait

    def jct_colocated(self, p_len: int, d_len: int, rps: float) -> float:
        """One PD-colocated TE with chunked prefill: decode steps are slowed
        by interleaved prefill chunks (interference), prefill is stretched
        by sharing the token budget with decodes."""
        t_p = self.cost.prefill_time(p_len)
        batch = max(1, min(16, int(rps * d_len * self.cost.decode_step_time(8, p_len) * 8)))
        # chunked prefill shares each step with decode: prefill stretched,
        # decode steps pay the chunk's compute (interference term).
        chunk = 512
        n_chunks = max(1, p_len // chunk)
        t_chunk = self.cost.prefill_time(chunk, kv_context=p_len // 2)
        decode_step = self.cost.decode_step_time(batch, p_len + d_len // 2)
        # while prefilling a new request, concurrent decodes slow down:
        interference = n_chunks * max(0.0, t_chunk - decode_step * 0.2)
        t_d = self.cost.decode_time(d_len, batch, p_len) + interference
        util = min(0.95, rps * (t_p + t_d) / max(batch, 1))
        wait = (util / max(1e-9, (1 - util))) * (t_p + t_d) / max(batch, 1) * 0.5
        return t_p + t_d + wait

    # ---------------------------------------------------------------- grid
    def compute(self, rps: float) -> np.ndarray:
        grid = np.zeros((len(self.prefill_lens), len(self.decode_ratios)))
        for i, pl in enumerate(self.prefill_lens):
            for j, r in enumerate(self.decode_ratios):
                dl = max(1, int(pl * r))
                jd = self.jct_disaggregated(pl, dl, rps)
                jc = self.jct_colocated(pl, dl, rps)
                grid[i, j] = jc / jd - 1.0
        return grid

    def combined(self) -> np.ndarray:
        """Element-wise sum across all RPS values (§5.3.2 step 1)."""
        return np.sum([self.compute(r) for r in self.rps_grid], axis=0)

    def stability(self) -> float:
        """Fraction of cells with a consistent sign across RPS values (the
        paper reports >80%)."""
        grids = np.stack([self.compute(r) for r in self.rps_grid])
        signs = np.sign(grids)
        consistent = np.all(signs == signs[0], axis=0)
        return float(np.mean(consistent))


def lookup(combined: np.ndarray, prefill_lens, decode_ratios,
           p_len: int, d_len: int) -> float:
    """Nearest-cell lookup used by select_tes_PD_heatmap."""
    i = int(np.argmin([abs(p_len - x) for x in prefill_lens]))
    ratio = d_len / max(p_len, 1)
    j = int(np.argmin([abs(ratio - x) for x in decode_ratios]))
    return float(combined[i, j])
