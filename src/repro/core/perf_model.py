"""Analytic serving-performance model (fidelity tier T3, DESIGN.md §3).

Calibrated from the dry-run roofline terms and the v5e hardware constants,
this model prices prefill/decode work on a TE so cluster-scale experiments
(Figures 4, 6, 7) exercise the *real* scheduling code against realistic
timings. The paper measures the same quantities on Ascend hardware.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

from repro.configs.base import ModelConfig

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip (v5e)
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link
MFU_PREFILL = 0.55         # achievable fraction of peak in prefill
MBU_DECODE = 0.70          # achievable fraction of HBM bw in decode
STEP_OVERHEAD = 2.0e-3     # per-engine-step host/dispatch overhead (s)


@dataclass
class TEHardware:
    n_chips: int = 4                     # e.g. TP=4 like the paper's 34B tests
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW


@dataclass
class TECostModel:
    """Prices one TE's work for a given model config."""
    cfg: ModelConfig
    hw: TEHardware = field(default_factory=TEHardware)
    kv_bytes_per_token: Optional[float] = None

    def __post_init__(self):
        c = self.cfg
        if self.kv_bytes_per_token is None:
            la = sum(1 for k in c.layer_kinds() if k.startswith("attn"))
            self.kv_bytes_per_token = 2 * la * c.n_kv_heads * c.head_dim * 2  # bf16

    # ------------------------------------------------------------ prefill
    def prefill_time(self, n_tokens: int, kv_context: int = 0) -> float:
        """Compute-bound: 2·N_active FLOPs/token + attention quadratic term."""
        c = self.cfg
        flops = 2.0 * c.active_param_count() * n_tokens
        # attention score/AV FLOPs: 4 * L * H * hd * S_kv per token
        la = sum(1 for k in c.layer_kinds() if k.startswith("attn"))
        avg_ctx = kv_context + n_tokens / 2
        if c.window:
            avg_ctx = min(avg_ctx, c.window)
        flops += 4.0 * la * c.n_heads * c.head_dim * avg_ctx * n_tokens
        return flops / (self.hw.n_chips * self.hw.peak_flops * MFU_PREFILL)

    # ------------------------------------------------------------ decode
    def decode_step_time(self, batch: int, avg_context: int) -> float:
        """Memory-bound: stream weights once per step + KV per sequence."""
        c = self.cfg
        weight_bytes = 2.0 * c.active_param_count()     # bf16
        ctx = min(avg_context, c.window) if c.window else avg_context
        kv_bytes = batch * self.kv_bytes_per_token * ctx
        t_mem = (weight_bytes + kv_bytes) / (self.hw.n_chips * self.hw.hbm_bw * MBU_DECODE)
        t_flops = (2.0 * c.active_param_count() * batch
                   / (self.hw.n_chips * self.hw.peak_flops * MFU_PREFILL))
        return max(t_mem, t_flops) + STEP_OVERHEAD

    def decode_time(self, n_tokens: int, batch: int, context0: int) -> float:
        """Total time to emit n_tokens per sequence at a fixed batch."""
        total = 0.0
        for i in range(n_tokens):
            total += self.decode_step_time(batch, context0 + i)
        return total
