"""Cluster manager + Job/Task executors (§3) and the AUTOSCALER (§6).

The cluster manager is the HA control plane: TE-group membership, health
(heartbeats, reboot-on-failure per §7), and scaling triggered by load /
SLO-violation metrics. JEs pull requests, decompose them (request-job-task)
and drive the distributed scheduler; TEs wrap FLOWSERVE engines behind the
TE-shell (health + scaling hooks).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core.abstractions import (Job, JobKind, Status, Task, TaskKind,
                                     UserRequest, decompose)
from repro.core.fleet import TEState, advance
from repro.core.scaling import FastScaler, ModelAsset
from repro.core.scheduling import DistributedScheduler, SchedRequest, TEHandle


# ---------------------------------------------------------------------------
# Task executor (TE-shell around an engine)
# ---------------------------------------------------------------------------


@dataclass
class TaskExecutor:
    te_id: str
    te_type: str                         # "colocated" | "prefill" | "decode"
    engine: Any = None                   # FlowServe (live) or sim cost model
    healthy: bool = True
    state: TEState = TEState.SERVING     # lifecycle (core/fleet.py)
    last_heartbeat: float = field(default_factory=time.monotonic)
    tasks_done: int = 0

    def transition(self, new: TEState) -> TEState:
        """Validated lifecycle walk; illegal transitions raise."""
        self.state = advance(self.state, new)
        return self.state

    def drained(self) -> bool:
        """A DRAINING TE is releasable once its engine holds no work."""
        return self.state is TEState.DRAINING and (
            self.engine is None or not getattr(self.engine, "has_work",
                                               lambda: False)())

    def heartbeat(self) -> None:
        self.last_heartbeat = time.monotonic()

    def fail(self) -> None:
        """Mark the TE crashed: unhealthy + lifecycle FAILED (legal from
        SERVING/DRAINING/WARMING; a TE already RELEASED stays released)."""
        self.healthy = False
        if self.state in (TEState.SERVING, TEState.DRAINING,
                          TEState.WARMING):
            self.transition(TEState.FAILED)

    def reboot(self) -> None:
        """§7: reboot the component; RTC state is soft (recomputed), so no
        consistency protocol is needed. A FAILED TE walks the legal
        FAILED → WARMING → SERVING path back (reboot-in-place)."""
        self.healthy = True
        self.heartbeat()
        if self.state is TEState.FAILED:
            self.transition(TEState.WARMING)
            self.transition(TEState.SERVING)
        if self.engine is not None and getattr(self.engine, "rtc", None) is not None:
            # soft state: drop the prefix index; pages are reclaimed lazily
            from repro.engine.rtc import RelationalTensorCache
            eng = self.engine
            eng.rtc = RelationalTensorCache(eng.pool, eng.rtc.cost)
            eng.scheduler.rtc = eng.rtc


# ---------------------------------------------------------------------------
# Job executor
# ---------------------------------------------------------------------------


class JobExecutor:
    """Model-serving JE: decomposes requests and dispatches tasks to TEs via
    the distributed scheduler (Algorithm 1)."""

    def __init__(self, je_id: str, scheduler: DistributedScheduler,
                 dispatch: Callable[[Task, TEHandle], Any]):
        self.je_id = je_id
        self.scheduler = scheduler
        self.dispatch = dispatch
        self.jobs: Dict[str, Job] = {}
        self.healthy = True

    def handle(self, request: UserRequest) -> List[Job]:
        jobs = decompose(request)
        for job in jobs:
            self.jobs[job.job_id] = job
            if job.kind == JobKind.SERVING:
                self._serve(job)
            else:
                # post-training jobs: one shard task (training substrate)
                task = job.spawn(TaskKind.TRAIN_SHARD if job.kind == JobKind.TRAINING
                                 else TaskKind.PREPROCESS_SHARD,
                                 payload=request.payload)
                task.status = Status.PENDING
        return jobs

    def _serve(self, job: Job) -> None:
        tokens = job.request.payload["tokens"]
        sreq = SchedRequest(tokens=tokens,
                            predicted_decode=job.request.payload.get("max_new_tokens", 128))
        te = self.scheduler.dist_sched(sreq)
        self.scheduler.commit(sreq, te)
        if te.te_type == "pd_pair":
            t1 = job.spawn(TaskKind.PREFILL, tokens=tokens)
            t2 = job.spawn(TaskKind.DECODE, tokens=tokens)
            t1.te_id = te.te_id + "/prefill"
            t2.te_id = te.te_id + "/decode"
            self.dispatch(t1, te)
            self.dispatch(t2, te)
        else:
            t = job.spawn(TaskKind.COLOCATED, tokens=tokens)
            t.te_id = te.te_id
            self.dispatch(t, te)


# ---------------------------------------------------------------------------
# Cluster manager + autoscaler
# ---------------------------------------------------------------------------


@dataclass
class AutoscalerConfig:
    high_load: float = 0.80              # scale-up trigger (pool utilization)
    low_load: float = 0.25               # scale-down trigger
    slo_violation_rate: float = 0.05
    cooldown_s: float = 5.0
    max_tes: int = 64
    min_tes: int = 1


class ClusterManager:
    """Centralized HA module: membership, health, autoscaling."""

    def __init__(self, scaler: FastScaler, asset: ModelAsset,
                 cfg: AutoscalerConfig = AutoscalerConfig(),
                 te_factory: Optional[Callable[[str], TaskExecutor]] = None,
                 heartbeat_timeout: float = 10.0):
        self.scaler = scaler
        self.asset = asset
        self.cfg = cfg
        self.te_factory = te_factory or (lambda te_id: TaskExecutor(te_id, "colocated"))
        self.tes: Dict[str, TaskExecutor] = {}
        self.jes: Dict[str, JobExecutor] = {}
        self._te_seq = 0                 # monotonic: drain holes must not
        #                                  recycle a live TE's id
        self._last_scale = 0.0
        self.heartbeat_timeout = heartbeat_timeout
        self.scale_log: List[Dict[str, Any]] = []

    # ------------------------------------------------------------- health
    def check_health(self) -> List[str]:
        """Reboot TEs whose heartbeat lapsed (§7 fault recovery)."""
        rebooted = []
        now = time.monotonic()
        for te in self.tes.values():
            if not te.healthy or te.state is TEState.FAILED \
                    or now - te.last_heartbeat > self.heartbeat_timeout:
                te.reboot()
                rebooted.append(te.te_id)
        return rebooted

    # ------------------------------------------------------------- scaling
    def autoscale(self, load: float, slo_violations: float,
                  now: Optional[float] = None) -> int:
        """Returns TE delta applied (positive = scaled up)."""
        now = now if now is not None else time.monotonic()
        # earlier drains may have emptied since the last evaluation — reap
        # on EVERY tick (a victim that lingered past its drain decision
        # would otherwise leak: the low-load branch is gated on
        # n_serving() > min_tes and can stop re-entering forever)
        self.reap_drained()
        if now - self._last_scale < self.cfg.cooldown_s:
            return 0
        n = len(self.tes)
        delta = 0
        if (load > self.cfg.high_load or slo_violations > self.cfg.slo_violation_rate) \
                and n < self.cfg.max_tes:
            delta = min(max(1, n), self.cfg.max_tes - n)   # double, capped
            for _ in range(delta):
                ev = self.scaler.scale_one(self.asset, optimized=True)
                while f"te-{self._te_seq}" in self.tes:   # externally
                    self._te_seq += 1                     # registered ids
                te = self.te_factory(f"te-{self._te_seq}")
                self._te_seq += 1
                self.tes[te.te_id] = te
                self.scale_log.append({"dir": "up", "event": ev.total,
                                       "path": ev.path, "t": now})
        elif load < self.cfg.low_load and self.n_serving() > self.cfg.min_tes:
            # scale-in is a DRAIN, not a delete (lifecycle, core/fleet.py):
            # the victim stops admitting, empties, then reap_drained()
            # releases its resources — a TE with no engine drains instantly
            victim = next((self.tes[tid] for tid in reversed(self.tes)
                           if self.tes[tid].state is TEState.SERVING), None)
            if victim is not None:
                delta = -1
                victim.transition(TEState.DRAINING)
                self.scale_log.append({"dir": "down", "te_id": victim.te_id,
                                       "t": now})
                self.reap_drained()
        if delta:
            self._last_scale = now
        return delta

    def n_serving(self) -> int:
        return sum(1 for te in self.tes.values()
                   if te.state is TEState.SERVING)

    def reap_drained(self) -> List[str]:
        """Release every DRAINING TE that has emptied: transition to
        RELEASED, return its pre-warm resources, drop it from membership.
        With a warm pool on the scaler (DESIGN.md §10), a live engine's
        device-resident params drain back to host DRAM on the way out, so
        the next scale-up takes the warm path instead of reloading."""
        released = []
        warm = getattr(self.scaler, "warm", None)
        for te_id in [t for t, te in self.tes.items() if te.drained()]:
            te = self.tes[te_id]
            te.transition(TEState.RELEASED)
            if warm is not None and te.engine is not None \
                    and hasattr(te.engine, "release_params"):
                host = te.engine.release_params(
                    to_host=not warm.hit(self.asset.name))
                if host is not None:
                    warm.put(self.asset.name, host, host_copy=False)
            self.scaler.release(te_id)
            del self.tes[te_id]
            released.append(te_id)
        return released

    def register_te(self, te: TaskExecutor) -> None:
        self.tes[te.te_id] = te

    def register_je(self, je: JobExecutor) -> None:
        self.jes[je.je_id] = je
