"""Decode-length predict model (§5.3.3).

TetriServe-style: a lightweight classifier buckets the expected decode
length (bucket granularity 128 tokens in the paper; configurable here).
The paper trains OPT-125M on (prompt → observed target-LLM decode length);
we train a small JAX MLP over bag-of-token-features on a synthetically
generated corpus whose decode lengths correlate with prompt statistics the
way real traces do (code prompts → long, short chat → short). The paper
reports 84.9% accuracy; our target is ≥80% on the held-out split, which
benchmarks/bench_predictor.py verifies.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class PredictorConfig:
    bucket_size: int = 128
    n_buckets: int = 8
    n_features: int = 64
    hidden: int = 128
    lr: float = 3e-3
    steps: int = 300
    batch: int = 256


def featurize(prompt_tokens: np.ndarray, n_features: int) -> np.ndarray:
    """Cheap prompt features: length stats + hashed bag-of-tokens."""
    f = np.zeros((n_features,), np.float32)
    n = len(prompt_tokens)
    f[0] = math.log1p(n) / 10.0
    f[1] = (n % 97) / 97.0
    if n:
        f[2] = float(np.mean(prompt_tokens)) / 260.0
        f[3] = float(np.std(prompt_tokens)) / 130.0
        idx = (prompt_tokens * 2654435761 % (n_features - 4)).astype(np.int64)
        np.add.at(f, 4 + idx, 1.0 / max(n, 1))
    return f


def synth_trace(n: int, cfg: PredictorConfig, seed: int = 0
                ) -> Tuple[np.ndarray, np.ndarray, List[np.ndarray]]:
    """Synthetic (prompt, decode-length) pairs with learnable structure:
    three latent request classes (chat / code / summarize) with different
    token distributions and decode-length regimes + noise."""
    rng = np.random.RandomState(seed)
    xs, ys, prompts = [], [], []
    for _ in range(n):
        cls = rng.randint(3)
        if cls == 0:    # chat: short prompt, short decode
            plen = rng.randint(8, 64)
            toks = rng.randint(3, 120, plen)
            dlen = 40 + plen + int(rng.randn() * 14)
        elif cls == 1:  # code: marker tokens, long decode
            plen = rng.randint(32, 256)
            toks = np.concatenate([rng.randint(120, 200, plen - 4), [123, 125, 40, 41]])
            dlen = 520 + plen // 2 + int(rng.randn() * 36)
        else:           # summarize: long prompt, medium decode
            plen = rng.randint(256, 512)
            toks = rng.randint(3, 255, plen)
            dlen = 140 + plen // 4 + int(rng.randn() * 24)
        dlen = int(np.clip(dlen, 1, cfg.bucket_size * cfg.n_buckets - 1))
        xs.append(featurize(toks, cfg.n_features))
        ys.append(dlen // cfg.bucket_size)
        prompts.append(toks)
    return np.stack(xs), np.asarray(ys, np.int32), prompts


def init_predictor(cfg: PredictorConfig, key) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (cfg.n_features, cfg.hidden)) * (1 / math.sqrt(cfg.n_features)),
        "b1": jnp.zeros((cfg.hidden,)),
        "w2": jax.random.normal(k2, (cfg.hidden, cfg.n_buckets)) * (1 / math.sqrt(cfg.hidden)),
        "b2": jnp.zeros((cfg.n_buckets,)),
    }


def predictor_logits(params: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def train_predictor(cfg: PredictorConfig, xs: np.ndarray, ys: np.ndarray,
                    seed: int = 0) -> Tuple[dict, float]:
    """Adam-trained classifier; returns (params, held-out accuracy)."""
    n = len(xs)
    n_tr = int(n * 0.8)
    xtr, ytr = jnp.asarray(xs[:n_tr]), jnp.asarray(ys[:n_tr])
    xte, yte = jnp.asarray(xs[n_tr:]), jnp.asarray(ys[n_tr:])
    params = init_predictor(cfg, jax.random.PRNGKey(seed))
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    def loss_fn(p, xb, yb):
        lg = predictor_logits(p, xb)
        return -jnp.mean(jnp.take_along_axis(jax.nn.log_softmax(lg), yb[:, None], 1))

    @jax.jit
    def step(p, m, v, xb, yb, t):
        g = jax.grad(loss_fn)(p, xb, yb)
        m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        mh = jax.tree.map(lambda a: a / (1 - 0.9 ** t), m)
        vh = jax.tree.map(lambda a: a / (1 - 0.999 ** t), v)
        p = jax.tree.map(lambda a, mm, vv: a - cfg.lr * mm / (jnp.sqrt(vv) + 1e-8),
                         p, mh, vh)
        return p, m, v

    rng = np.random.RandomState(seed)
    for t in range(1, cfg.steps + 1):
        idx = rng.randint(0, n_tr, cfg.batch)
        params, m, v = step(params, m, v, xtr[idx], ytr[idx], t)
    acc = float(jnp.mean(jnp.argmax(predictor_logits(params, xte), -1) == yte))
    return params, acc


class TraceEMAPredictor:
    """Online decode-length estimator trained from completed-request traces
    (DESIGN.md §9, the serving plane's default).

    The offline MLP (``DecodeLengthPredictor``) needs a labeled corpus; the
    live plane has something better — its own completions. Requests bucket
    into a *mix* by log2 prompt length (the serving mixes — chat vs code vs
    summarize vs agent turns — separate cleanly by prompt scale), and each
    bucket keeps an exponential moving average of observed decode lengths.
    ``ServingJobEngine`` calls ``observe`` per completion and
    ``predict_tokens`` per placement, so ``SchedRequest.predicted_decode``
    converges to the mix's real decode behavior instead of parroting the
    sampling budget. Implements the same ``predict_tokens`` interface
    ``DistributedScheduler.pd_aware`` already consumes."""

    def __init__(self, alpha: float = 0.25, default_guess: int = 64,
                 n_bins: int = 12):
        self.alpha = alpha
        self.default_guess = default_guess
        self.n_bins = n_bins
        self._ema: dict = {}            # bin -> EMA decode length
        self._count: dict = {}          # bin -> observations

    def _bin(self, prompt_tokens) -> int:
        n = max(1, len(prompt_tokens))
        return min(self.n_bins - 1, int(math.log2(n)))

    def observe(self, prompt_tokens, decode_len: int) -> None:
        b = self._bin(prompt_tokens)
        cur = self._ema.get(b)
        self._ema[b] = (float(decode_len) if cur is None
                        else (1.0 - self.alpha) * cur
                        + self.alpha * float(decode_len))
        self._count[b] = self._count.get(b, 0) + 1

    def predict_tokens(self, prompt_tokens) -> int:
        b = self._bin(prompt_tokens)
        if b in self._ema:
            return max(1, int(round(self._ema[b])))
        if self._ema:               # nearest trained mix beats the default
            nearest = min(self._ema, key=lambda k: abs(k - b))
            return max(1, int(round(self._ema[nearest])))
        return self.default_guess

    def n_observations(self) -> int:
        return sum(self._count.values())


class DecodeLengthPredictor:
    """Inference-side wrapper used by PD-aware scheduling."""

    def __init__(self, cfg: PredictorConfig, params: dict):
        self.cfg = cfg
        self.params = params
        self._fn = jax.jit(lambda x: jnp.argmax(predictor_logits(params, x), -1))

    def predict_bucket(self, prompt_tokens) -> int:
        x = jnp.asarray(featurize(np.asarray(prompt_tokens), self.cfg.n_features))[None]
        return int(self._fn(x)[0])

    def predict_tokens(self, prompt_tokens) -> int:
        b = self.predict_bucket(prompt_tokens)
        return b * self.cfg.bucket_size + self.cfg.bucket_size // 2
