"""Sharded checkpoint save/restore with async writes (fault tolerance).

Layout: <dir>/step_<N>/
    manifest.json            — pytree structure, shapes, dtypes, shard map
    shard_<i>.npz            — flat leaves, split round-robin into shards
On a real cluster each host writes only the leaves it owns (process-local
shards of the GSPMD-sharded arrays); here shards model that layout so
restore-with-resharding is exercised. Writes can be async (background
thread) so the train loop never blocks — ``wait()`` joins before exit, and
a crashed step simply resumes from the last complete manifest (atomic
rename marks completeness).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


class CheckpointManager:
    def __init__(self, directory: str, n_shards: int = 4, keep: int = 3):
        self.dir = directory
        self.n_shards = n_shards
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, blocking: bool = True) -> None:
        self.wait()
        # snapshot to host memory synchronously (cheap), write async
        named = [(k, np.asarray(v)) for k, v in _flatten_with_paths(tree)]
        treedef = jax.tree.structure(tree)

        def _write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            shards: List[Dict[str, np.ndarray]] = [dict() for _ in range(self.n_shards)]
            manifest = {"step": step, "treedef": str(treedef), "leaves": []}
            for i, (k, arr) in enumerate(named):
                si = i % self.n_shards
                key = f"leaf_{i}"
                dtype = str(arr.dtype)
                if dtype == "bfloat16":  # npz has no bf16: store f32 losslessly
                    arr = arr.astype(np.float32)
                shards[si][key] = arr
                manifest["leaves"].append(
                    {"path": k, "key": key, "shard": si,
                     "shape": list(arr.shape), "dtype": dtype})
            for si, sh in enumerate(shards):
                np.savez(os.path.join(tmp, f"shard_{si}.npz"), **sh)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)       # atomic completeness marker
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.list_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # ------------------------------------------------------------- load
    def list_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, like_tree, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of `like_tree`; `shardings` (optional
        matching pytree of NamedSharding) re-shards on load (elastic
        restart on a different mesh)."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        shard_files = {}
        leaves_np = {}
        for meta in manifest["leaves"]:
            si = meta["shard"]
            if si not in shard_files:
                shard_files[si] = np.load(os.path.join(d, f"shard_{si}.npz"))
            leaves_np[meta["path"]] = shard_files[si][meta["key"]]
        flat = _flatten_with_paths(like_tree)
        restored = []
        for k, ref in flat:
            arr = leaves_np[k]
            assert list(arr.shape) == list(ref.shape), (k, arr.shape, ref.shape)
            restored.append(jnp.asarray(arr).astype(ref.dtype))
        out = jax.tree.unflatten(jax.tree.structure(like_tree), restored)
        if shardings is not None:
            out = jax.device_put(out, shardings)
        return out
