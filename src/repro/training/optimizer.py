"""AdamW + global-norm clipping + cosine schedule, pure JAX (no optax).

Optimizer state is a pytree shaped like the params (m, v in fp32) so the
launcher can shard it ZeRO-style over the data axis (DESIGN.md §5).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac * cfg.lr + 0.5 * (1 - cfg.min_lr_frac) * cfg.lr \
        * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(cfg: OptimizerConfig, params, grads, opt_state):
    """Returns (new_params, new_opt_state, metrics). Params keep their dtype
    (bf16 weights, fp32 moments — standard mixed-precision training)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.betas

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / (1 - b1 ** step.astype(jnp.float32))
        vh = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([n[0] for n in new])
    new_m = tdef.unflatten([n[1] for n in new])
    new_v = tdef.unflatten([n[2] for n in new])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
