from repro.training.optimizer import OptimizerConfig, adamw_update, init_opt_state  # noqa: F401
from repro.training.checkpoint import CheckpointManager  # noqa: F401
from repro.training.train_loop import TrainConfig, train, make_train_step  # noqa: F401
