"""Training loop for fine-tune jobs (the TRAINING job kind of §3).

train_step = fwd (remat over layers) → grads → AdamW, optionally with
gradient (microbatch) accumulation. The same step function is what the
dry-run lowers onto the production mesh for the train_4k shapes.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model_factory import ModelBundle, cross_entropy
from repro.training.checkpoint import CheckpointManager
from repro.training.optimizer import OptimizerConfig, adamw_update, init_opt_state


@dataclass
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    microbatches: int = 1
    remat: bool = True
    opt: OptimizerConfig = OptimizerConfig()


def make_loss_fn(bundle: ModelBundle, remat: bool = True):
    cfg = bundle.cfg

    def loss_fn(params, tokens, targets, mask, extra):
        logits = bundle.forward(cfg, params, tokens, attn_impl="auto",
                                remat=remat, **extra)
        return cross_entropy(logits, targets, mask, cfg.vocab_size)

    return loss_fn


def make_train_step(bundle: ModelBundle, tcfg: TrainConfig):
    loss_fn = make_loss_fn(bundle, tcfg.remat)

    def train_step(params, opt_state, tokens, targets, mask, extra):
        if tcfg.microbatches > 1:
            mb_tok = jnp.reshape(tokens, (tcfg.microbatches, -1) + tokens.shape[1:])
            mb_tgt = jnp.reshape(targets, (tcfg.microbatches, -1) + targets.shape[1:])
            mb_msk = jnp.reshape(mask, (tcfg.microbatches, -1) + mask.shape[1:])

            def acc_body(carry, xs):
                g_acc, l_acc = carry
                t, y, m = xs
                l, g = jax.value_and_grad(loss_fn)(params, t, y, m, extra)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

            zero_g = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(acc_body, (zero_g, 0.0),
                                            (mb_tok, mb_tgt, mb_msk))
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, grads)
            loss = loss / tcfg.microbatches
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, tokens, targets,
                                                      mask, extra)
        params, opt_state, metrics = adamw_update(tcfg.opt, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def train(bundle: ModelBundle, params, data_iter, tcfg: TrainConfig,
          ckpt: Optional[CheckpointManager] = None,
          resume: bool = False,
          log: Callable[[str], None] = print) -> Tuple[Any, Dict[str, float]]:
    opt_state = init_opt_state(params)
    start_step = 0
    if ckpt is not None and resume and ckpt.latest_step() is not None:
        state = ckpt.restore({"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        start_step = int(opt_state["step"])
        log(f"resumed from step {start_step}")
    step_fn = jax.jit(make_train_step(bundle, tcfg))
    extra = bundle.extra_inputs(1)
    history = []
    t0 = time.monotonic()
    for step in range(start_step, tcfg.steps):
        tokens, targets, mask = next(data_iter)
        ex = {k: jnp.broadcast_to(v, (tokens.shape[0],) + v.shape[1:])
              for k, v in extra.items()}
        params, opt_state, metrics = step_fn(params, opt_state,
                                             jnp.asarray(tokens), jnp.asarray(targets),
                                             jnp.asarray(mask), ex)
        history.append(float(metrics["loss"]))
        if (step + 1) % tcfg.log_every == 0:
            log(f"step {step+1}: loss={history[-1]:.4f} "
                f"gnorm={float(metrics['grad_norm']):.3f} "
                f"lr={float(metrics['lr']):.2e}")
        if ckpt is not None and (step + 1) % tcfg.ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state},
                      blocking=False)
    if ckpt is not None:
        ckpt.save(tcfg.steps, {"params": params, "opt": opt_state})
        ckpt.wait()
    return params, {"loss_first": history[0] if history else float("nan"),
                    "loss_last": history[-1] if history else float("nan"),
                    "wall": time.monotonic() - t0}
