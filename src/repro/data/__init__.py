from repro.data.pipeline import DataConfig, PackedDataset, synthetic_corpus  # noqa: F401
