"""Data pipeline for fine-tune jobs: tokenize → pack → shard.

Deterministic synthetic corpus (seeded) + document packing into fixed
seq_len windows with EOS separators, sharded by (host, data-parallel rank)
so multi-host training reads disjoint streams. On a real cluster the
source would be a file list; the pipeline interface is identical.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Iterator, List, Optional, Tuple

import numpy as np

from repro.engine.tokenizer import BOS_ID, EOS_ID, ByteTokenizer


@dataclass
class DataConfig:
    seq_len: int = 128
    batch_size: int = 8
    seed: int = 0
    n_docs: int = 2048
    dp_rank: int = 0
    dp_size: int = 1


_WORDS = ("serve scale pod engine cache prefill decode token flow tensor "
          "schedule cluster shard expert attention state page fork warm dram "
          "npu link transfer batch queue master executor radix prefix").split()


def synthetic_corpus(cfg: DataConfig) -> Iterator[str]:
    rng = np.random.RandomState(cfg.seed)
    for i in range(cfg.n_docs):
        n = rng.randint(8, 64)
        words = [_WORDS[rng.randint(len(_WORDS))] for _ in range(n)]
        yield f"doc{i}: " + " ".join(words) + "."


class PackedDataset:
    """Packs tokenized docs into (batch, seq_len+1) windows; iterating
    yields (tokens, targets, mask) ready for the train step."""

    def __init__(self, cfg: DataConfig, tokenizer: Optional[ByteTokenizer] = None,
                 docs: Optional[List[str]] = None):
        self.cfg = cfg
        tok = tokenizer or ByteTokenizer()
        stream: List[int] = []
        for i, doc in enumerate(docs if docs is not None else synthetic_corpus(cfg)):
            if i % cfg.dp_size != cfg.dp_rank:
                continue  # host/data shard
            stream.extend(tok.encode(doc) + [EOS_ID])
        window = cfg.seq_len + 1
        n_win = len(stream) // window
        self.windows = np.asarray(stream[: n_win * window],
                                  np.int32).reshape(n_win, window)

    def __len__(self) -> int:
        return len(self.windows) // self.cfg.batch_size

    def batches(self, epochs: int = 1) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        rng = np.random.RandomState(self.cfg.seed + 1)
        for _ in range(epochs):
            order = rng.permutation(len(self.windows))
            bs = self.cfg.batch_size
            for i in range(len(self.windows) // bs):
                w = self.windows[order[i * bs:(i + 1) * bs]]
                tokens, targets = w[:, :-1], w[:, 1:]
                mask = (targets != 0).astype(np.float32)
                yield tokens, targets, mask
