"""Token-sequence radix tree (prefix index for RTC and the JE global
prompt trees — §5.2's ``select_tes_prefix_match`` shares this structure).

Each edge is labeled with a token run; each node stores an opaque payload
(page run for RTC, TE ids for the global tree) plus LRU metadata.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

_counter = itertools.count()


@dataclass
class RadixNode:
    key: Tuple[int, ...] = ()               # edge label from parent
    children: Dict[int, "RadixNode"] = field(default_factory=dict)
    payload: Any = None
    last_access: float = 0.0
    node_id: int = field(default_factory=lambda: next(_counter))
    parent: Optional["RadixNode"] = None

    def touch(self) -> None:
        self.last_access = time.monotonic()


def _common_prefix(a, b) -> int:
    n = min(len(a), len(b))
    i = 0
    while i < n and a[i] == b[i]:
        i += 1
    return i


class RadixTree:
    def __init__(self):
        self.root = RadixNode()

    def insert(self, tokens, payload: Any) -> RadixNode:
        """Insert `tokens`, splitting edges as needed; sets payload on the
        terminal node and returns it."""
        node = self.root
        tokens = tuple(tokens)
        while tokens:
            head = tokens[0]
            child = node.children.get(head)
            if child is None:
                new = RadixNode(key=tokens, parent=node)
                node.children[head] = new
                new.payload = payload
                new.touch()
                return new
            cp = _common_prefix(child.key, tokens)
            if cp == len(child.key):
                node = child
                node.touch()
                tokens = tokens[cp:]
                continue
            # split the edge
            mid = RadixNode(key=child.key[:cp], parent=node)
            child.key = child.key[cp:]
            child.parent = mid
            mid.children[child.key[0]] = child
            node.children[head] = mid
            mid.touch()
            node = mid
            tokens = tokens[cp:]
        node.payload = payload if tokens == () or node.payload is None else node.payload
        node.payload = payload
        node.touch()
        return node

    def match_prefix(self, tokens) -> Tuple[int, List[RadixNode]]:
        """Longest-prefix match, counting partial-edge matches. Returns
        (#matched tokens, node path). On a partial edge the edge's child is
        appended to the path: every payload in its subtree shares the first
        `matched` tokens with the query, so a caller can reuse that many
        tokens of any descendant entry (SGLang-style partial reuse)."""
        node = self.root
        tokens = tuple(tokens)
        matched = 0
        path: List[RadixNode] = []
        while tokens:
            child = node.children.get(tokens[0])
            if child is None:
                break
            cp = _common_prefix(child.key, tokens)
            matched += cp
            if cp < len(child.key):
                child.touch()
                path.append(child)
                break
            tokens = tokens[cp:]
            node = child
            node.touch()
            path.append(node)
        return matched, path

    def any_payload(self, node: RadixNode):
        """Any payload in `node`'s subtree (shallowest-first)."""
        stack = [node]
        while stack:
            n = stack.pop(0)
            if n.payload is not None:
                return n.payload
            stack.extend(n.children.values())
        return None

    def remove(self, node: RadixNode) -> None:
        """Remove a leaf node (payload eviction). Inner nodes keep structure."""
        if node.children or node.parent is None:
            node.payload = None
            return
        parent = node.parent
        parent.children.pop(node.key[0], None)
        # merge a now-single-child pass-through parent with its child
        if (parent.parent is not None and parent.payload is None
                and len(parent.children) == 1):
            (only,) = parent.children.values()
            only.key = parent.key + only.key
            only.parent = parent.parent
            parent.parent.children[parent.key[0]] = only

    def leaves_by_lru(self) -> List[RadixNode]:
        out: List[RadixNode] = []

        def walk(n: RadixNode):
            if not n.children and n.payload is not None:
                out.append(n)
            for c in n.children.values():
                walk(c)

        walk(self.root)
        out.sort(key=lambda n: n.last_access)
        return out

    def size(self) -> int:
        count = 0
        stack = [self.root]
        while stack:
            n = stack.pop()
            count += 1
            stack.extend(n.children.values())
        return count - 1  # exclude root
