"""Relational Tensor Cache (RTC) — §4.3, Table 1.

Unifies caching and memory management for one FLOWSERVE engine:
  * block table / page allocation        (AllocBlocks, AppendBlock, Free)
  * prefix-token radix index             (MatchByPrefixToken)
  * explicit-ID index                    (MatchByID — context-caching endpoint)
  * tiered storage NPU ↔ DRAM            (Copy, Populate, QueryPopulate)
  * a populate cost model: reuse cached KV only when fetching it is
    cheaper than recomputing the prefill (§4.2's "cost model" step)
  * SSM/hybrid archs: prefix entries are recurrent-state checkpoints
    (DESIGN.md §4) rather than per-token pages.

Master/executor split: this class is the master-side index + decision
maker; the data plane (page pools) is the executor side (PagedKVPool,
sharded per NPU on real hardware via the `model` axis).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.engine.kv_cache import OutOfPagesError, PagedKVPool, pages_needed
from repro.engine.radix_tree import RadixTree

_populate_ids = itertools.count()


@dataclass
class CacheEntry:
    """Payload of a radix-tree / ID-index node."""
    n_tokens: int
    location: str                       # "npu" | "dram"
    pages: Optional[List[int]] = None   # when on NPU (attention archs)
    dram_handle: Optional[int] = None   # when swapped out
    state: Any = None                   # SSM state checkpoint (host copy)
    node: Any = None                    # back-pointer to radix node


@dataclass
class MatchResult:
    matched_tokens: int
    entry: Optional[CacheEntry]
    location: str                       # "none" | "npu" | "dram"


@dataclass
class PopulateTicket:
    ticket: int
    entry: CacheEntry
    pages: List[int]
    done: bool = False


@dataclass
class RTCCostModel:
    """Reuse-vs-recompute decision (§4.2). Times in seconds; defaults are
    v5e-flavored: PCIe-class host link for DRAM fetch vs prefill compute."""
    fetch_bw_bytes: float = 25e9        # DRAM->NPU populate bandwidth
    prefill_flops_rate: float = 98e12   # achievable prefill FLOP/s (≈50% peak)
    flops_per_token: float = 2e9        # 2·N_active per token; set per model

    def fetch_time(self, n_bytes: int) -> float:
        return n_bytes / self.fetch_bw_bytes

    def recompute_time(self, n_tokens: int) -> float:
        return n_tokens * self.flops_per_token / self.prefill_flops_rate

    def should_fetch(self, n_bytes: int, n_tokens: int) -> bool:
        return self.fetch_time(n_bytes) < self.recompute_time(n_tokens)


class RelationalTensorCache:
    def __init__(self, pool: PagedKVPool, cost_model: Optional[RTCCostModel] = None,
                 state_based: bool = False):
        self.pool = pool
        self.tree = RadixTree()
        self.by_id: Dict[str, CacheEntry] = {}
        self.cost = cost_model or RTCCostModel()
        self.state_based = state_based
        self._pending: Dict[int, PopulateTicket] = {}
        self.stats = {"hits": 0, "misses": 0, "populates": 0, "evictions": 0,
                      "tokens_reused": 0}

    # ----------------------------------------------------------- matching
    def match_by_prefix_token(self, tokens) -> MatchResult:
        matched, path = self.tree.match_prefix(tokens)
        # deepest node on the path with a payload in its subtree; the first
        # `matched` tokens of any such entry equal the query's prefix
        for node in reversed(path):
            entry: Optional[CacheEntry] = node.payload or self.tree.any_payload(node)
            if entry is not None:
                self.stats["hits"] += 1
                return MatchResult(min(matched, entry.n_tokens), entry,
                                   entry.location)
        self.stats["misses"] += 1
        return MatchResult(0, None, "none")

    def match_by_id(self, ctx_id: str) -> MatchResult:
        entry = self.by_id.get(ctx_id)
        if entry is None:
            self.stats["misses"] += 1
            return MatchResult(0, None, "none")
        self.stats["hits"] += 1
        return MatchResult(entry.n_tokens, entry, entry.location)

    # ----------------------------------------------------------- alloc
    def alloc_blocks(self, n_tokens: int) -> List[int]:
        """AllocBlocks — pages for a prefill of n_tokens. Evicts cached
        pages (LRU) on pressure."""
        need = pages_needed(n_tokens, self.pool.page_size)
        self._ensure_free(need)
        return self.pool.alloc(need)

    def append_block(self) -> int:
        """AppendBlock — one page for decode growth."""
        self._ensure_free(1)
        return self.pool.alloc(1)[0]

    def free(self, pages: List[int], keep_cached: bool = False) -> None:
        self.pool.release(pages, keep_cached=keep_cached)

    def _ensure_free(self, need: int) -> None:
        if self.pool.free_page_count() >= need:
            return
        # LRU-evict cached prefix entries until we have room
        for leaf in self.tree.leaves_by_lru():
            if self.pool.free_page_count() >= need:
                break
            entry: CacheEntry = leaf.payload
            if entry.location == "npu" and entry.pages is not None:
                self.pool.release(entry.pages, keep_cached=True)
                self.pool.evict_cached(entry.pages)
                self.stats["evictions"] += 1
                entry.location = "evicted"
                entry.pages = None
                self.tree.remove(leaf)
        if self.pool.free_page_count() < need:
            raise OutOfPagesError(
                f"need {need}, free {self.pool.free_page_count()} after eviction")

    # ----------------------------------------------------------- preserve
    def preserve_prefix(self, tokens, pages: List[int],
                        ctx_id: Optional[str] = None,
                        state: Any = None) -> CacheEntry:
        """Pin a prefill's KV (or SSM state checkpoint) for reuse."""
        entry = CacheEntry(n_tokens=len(tokens), location="npu",
                           pages=list(pages) if pages else None, state=state)
        if pages:
            self.pool.retain(pages)
        node = self.tree.insert(tokens, entry)
        entry.node = node
        if ctx_id is not None:
            self.by_id[ctx_id] = entry
        return entry

    def copy_to_dram(self, entry: CacheEntry) -> None:
        """RTC Copy: swap an NPU-resident entry to the DRAM tier."""
        if entry.location != "npu" or not entry.pages:
            return
        entry.dram_handle = self.pool.copy_to_dram(entry.pages)
        self.pool.release(entry.pages, keep_cached=True)
        self.pool.evict_cached(entry.pages)
        entry.pages = None
        entry.location = "dram"

    # ----------------------------------------------------------- populate
    def populate(self, entry: CacheEntry) -> Optional[PopulateTicket]:
        """Async fetch of a DRAM-tier entry into fresh NPU pages. Returns a
        ticket (completion is pumped by the master loop via
        ``pump_populates``), or None if the cost model rejects the fetch."""
        if entry.location != "dram" or entry.dram_handle is None:
            return None
        n_bytes = self.pool.dram_bytes(entry.dram_handle)
        if not self.cost.should_fetch(n_bytes, entry.n_tokens):
            return None
        need = pages_needed(entry.n_tokens, self.pool.page_size)
        self._ensure_free(need)
        pages = self.pool.alloc(need)
        ticket = PopulateTicket(next(_populate_ids), entry, pages)
        self._pending[ticket.ticket] = ticket
        self.stats["populates"] += 1
        return ticket

    def query_populate(self, ticket: int) -> bool:
        t = self._pending.get(ticket)
        return bool(t and t.done)

    def pump_populates(self) -> List[PopulateTicket]:
        """Master-loop tick: complete pending transfers (the data plane —
        on hardware this is DistFlow DMA finishing asynchronously)."""
        done = []
        for t in list(self._pending.values()):
            if not t.done:
                self.pool.populate_from_dram(t.entry.dram_handle, t.pages)
                t.entry.pages = t.pages
                t.entry.location = "npu"
                self.pool.retain(t.pages)
                self.pool.release(t.pages)  # net: pinned once by the entry
                t.done = True
                done.append(t)
                del self._pending[t.ticket]
        return done

    def reuse(self, entry: CacheEntry, upto_tokens: Optional[int] = None) -> Tuple[int, List[int]]:
        """Pin an NPU-resident entry for a new request; returns
        (#reusable tokens, page run). For state-based archs the reusable
        token count snaps to the entry's checkpoint boundary."""
        if entry.location != "npu":
            return 0, []
        n = entry.n_tokens if upto_tokens is None else min(entry.n_tokens, upto_tokens)
        if self.state_based:
            pass  # state entries are exact-boundary by construction
        if entry.pages:
            # only whole pages up to n tokens are reusable
            ps = self.pool.page_size
            usable_pages = n // ps
            pages = entry.pages[:usable_pages]
            self.pool.retain(pages)
            self.stats["tokens_reused"] += usable_pages * ps
            return usable_pages * ps, pages
        self.stats["tokens_reused"] += n
        return n, []
