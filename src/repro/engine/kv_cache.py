"""Paged KV cache with tiered storage (RTC's data plane).

The NPU tier is a global page pool: k/v arrays of shape
(L, n_pages, page_size, Hkv, hd) — stacked over attention layers so the
jit'd decode step takes the whole pool as one donated operand. The DRAM
tier is a host-side dict of swapped-out page runs (numpy). Block tables
map sequences → page runs, exactly the vLLM/RTC block table. With a
TP-sharded engine (EngineConfig.tp > 1) the pool's KV-head dim is sharded
over the `model` mesh axis (pass ``sharding``); tier moves are DistFlow
DMAs on real hardware, device↔host copies here.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


class OutOfPagesError(RuntimeError):
    pass


@dataclass
class PageRef:
    """ref_count>0 pages are pinned (shared via prefix cache); cached pages
    are retained for reuse after release and reclaimed under pressure."""
    page_id: int
    ref_count: int = 0
    cached: bool = False


class PagedKVPool:
    """Global NPU-tier KV pool for the attention layers of one engine."""

    def __init__(self, cfg: ModelConfig, n_pages: int, page_size: int,
                 dtype=jnp.float32, sharding=None):
        from repro.models.serving import attn_layer_count
        self.cfg = cfg
        self.n_layers = attn_layer_count(cfg)
        self.page_size = page_size
        self.n_pages = n_pages
        self.sharding = sharding                 # NamedSharding over (…,Hkv,…)
        shape = (max(self.n_layers, 1), n_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
        if sharding is not None:
            self.k = jax.device_put(jnp.zeros(shape, dtype), sharding)
            self.v = jax.device_put(jnp.zeros(shape, dtype), sharding)
        else:
            self.k = jnp.zeros(shape, dtype)
            self.v = jnp.zeros(shape, dtype)
        self._free: List[int] = list(range(n_pages))
        self._refs: Dict[int, PageRef] = {}
        # DRAM tier: handle -> (k_np, v_np) of shape (L, NP_run, P, Hkv, hd)
        self.dram: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self._dram_next = 0
        # DistFlow v2 device path: cached jits + import instrumentation
        self._gather_jit = None
        self._scatter_jits: Dict[int, Any] = {}   # layer_start -> jit
        self.full_pool_copies = 0   # un-donated whole-pool rewrites (v1 path)
        self._scratch: int = -1     # hot-loop padding sink (DESIGN.md §8)

    # ------------------------------------------------------------- alloc
    def free_page_count(self) -> int:
        return len(self._free)

    def scratch_page(self) -> int:
        """Permanently-pinned sink page for the decode hot loop's bucket
        padding (DESIGN.md §8): padding rows of a bucketed block table point
        here, so their per-step KV write lands in a page nothing ever reads
        instead of corrupting live sequences. Allocated once, never freed."""
        if self._scratch < 0:
            self._scratch = self.alloc(1)[0]
        return self._scratch

    def alloc(self, n: int) -> List[int]:
        if len(self._free) < n:
            raise OutOfPagesError(f"need {n} pages, have {len(self._free)}")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._refs[p] = PageRef(p, ref_count=1)
        return pages

    def retain(self, pages: List[int]) -> None:
        for p in pages:
            self._refs[p].ref_count += 1

    def release(self, pages: List[int], keep_cached: bool = False) -> List[int]:
        """Drop a reference; zero-ref pages are kept cached (evictable) or
        returned to the free list. Returns freed page ids."""
        freed = []
        for p in pages:
            ref = self._refs[p]
            ref.ref_count -= 1
            if ref.ref_count <= 0:
                if keep_cached:
                    ref.cached = True
                    ref.ref_count = 0
                else:
                    del self._refs[p]
                    self._free.append(p)
                    freed.append(p)
        return freed

    def evict_cached(self, pages: List[int]) -> None:
        for p in pages:
            ref = self._refs.get(p)
            if ref is not None and ref.cached and ref.ref_count == 0:
                del self._refs[p]
                self._free.append(p)

    def reclaimable(self) -> List[int]:
        return [p for p, r in self._refs.items() if r.cached and r.ref_count == 0]

    # ------------------------------------------------------------- data
    def write_run(self, pages: List[int], offset: int,
                  k_new: jax.Array, v_new: jax.Array) -> None:
        """Write a token run into (pages, offset). k_new/v_new:
        (L, T, Hkv, hd) — all layers at once."""
        t = k_new.shape[1]
        ps = self.page_size
        flat = offset + np.arange(t)
        page_idx = jnp.asarray([pages[i // ps] for i in flat], jnp.int32)
        slot_idx = jnp.asarray(flat % ps, jnp.int32)
        self.k = self.k.at[:, page_idx, slot_idx].set(k_new)
        self.v = self.v.at[:, page_idx, slot_idx].set(v_new)

    def gather(self, pages: List[int]) -> Tuple[jax.Array, jax.Array]:
        idx = jnp.asarray(pages, jnp.int32)
        return self.k[:, idx], self.v[:, idx]       # (L, NP_run, P, Hkv, hd)

    # ---------------------------------------------------- DistFlow v2 data
    # Page runs have the same rank as the pool (L, NP_run, P, Hkv, hd), so
    # the pool's sharding spec applies to runs verbatim: runs stay sharded
    # by whole KV heads over `model` end to end.

    def run_sharding(self):
        """Placement a page-run payload should have on this pool's mesh
        (SingleDeviceSharding when the engine is unsharded)."""
        return self.sharding if self.sharding is not None else self.k.sharding

    def gather_device(self, pages: List[int]) -> Tuple[jax.Array, jax.Array]:
        """Sharded device-resident gather of a page run — the DistFlow v2
        export payload. One jit'd dispatch, shardings pinned pool→run; no
        host copy anywhere."""
        if self._gather_jit is None:
            if self.sharding is not None:
                repl = NamedSharding(self.sharding.mesh, P())
                self._gather_jit = jax.jit(
                    lambda k, v, i: (k[:, i], v[:, i]),
                    in_shardings=(self.sharding, self.sharding, repl),
                    out_shardings=(self.sharding, self.sharding))
            else:
                self._gather_jit = jax.jit(lambda k, v, i: (k[:, i], v[:, i]))
        return self._gather_jit(self.k, self.v, jnp.asarray(pages, jnp.int32))

    def scatter_run(self, pages: List[int], k_run: jax.Array, v_run: jax.Array,
                    layer_start: int = 0) -> None:
        """Import-side page-run scatter: ONE donated jit'd dispatch with
        pinned in/out shardings — the pool is updated in place, never
        rewritten through the host. ``layer_start`` supports layer-chunked
        migration (the run covers layers [layer_start, layer_start+len))."""
        fn = self._scatter_jits.get(layer_start)
        if fn is None:
            l0 = layer_start

            def step(k, v, idx, k_run, v_run):
                li = l0 + jnp.arange(k_run.shape[0], dtype=jnp.int32)
                return (k.at[li[:, None], idx[None, :]].set(k_run),
                        v.at[li[:, None], idx[None, :]].set(v_run))

            if self.sharding is not None:
                repl = NamedSharding(self.sharding.mesh, P())
                fn = jax.jit(step, donate_argnums=(0, 1),
                             in_shardings=(self.sharding, self.sharding, repl,
                                           self.sharding, self.sharding),
                             out_shardings=(self.sharding, self.sharding))
            else:
                fn = jax.jit(step, donate_argnums=(0, 1))
            self._scatter_jits[layer_start] = fn
        self.k, self.v = fn(self.k, self.v, jnp.asarray(pages, jnp.int32),
                            k_run, v_run)

    # ------------------------------------------------------------- tiers
    def copy_to_dram(self, pages: List[int]) -> int:
        """RTC `Copy`: NPU → DRAM. Returns a DRAM handle."""
        idx = jnp.asarray(pages, jnp.int32)
        k_np = np.asarray(self.k[:, idx])
        v_np = np.asarray(self.v[:, idx])
        handle = self._dram_next
        self._dram_next += 1
        self.dram[handle] = (k_np, v_np)
        return handle

    def populate_from_dram(self, handle: int, pages: List[int]) -> None:
        """RTC `Populate` data plane: DRAM → NPU into allocated pages."""
        k_np, v_np = self.dram[handle]
        idx = jnp.asarray(pages, jnp.int32)
        self.k = self.k.at[:, idx].set(jnp.asarray(k_np[:, :len(pages)]))
        self.v = self.v.at[:, idx].set(jnp.asarray(v_np[:, :len(pages)]))

    def dram_bytes(self, handle: int) -> int:
        k_np, v_np = self.dram[handle]
        return k_np.nbytes + v_np.nbytes

    def drop_dram(self, handle: int) -> None:
        self.dram.pop(handle, None)

    def pool_bytes(self) -> int:
        return int(np.prod(self.k.shape)) * self.k.dtype.itemsize * 2


def pages_needed(n_tokens: int, page_size: int) -> int:
    return (n_tokens + page_size - 1) // page_size
