"""Paged KV cache with tiered storage (RTC's data plane).

The NPU tier is a global page pool: k/v arrays of shape
(L, n_pages, page_size, Hkv, hd) — stacked over attention layers so the
jit'd decode step takes the whole pool as one donated operand. The DRAM
tier is a host-side dict of swapped-out page runs (numpy). Block tables
map sequences → page runs, exactly the vLLM/RTC block table. With a
TP-sharded engine (EngineConfig.tp > 1) the pool's KV-head dim is sharded
over the `model` mesh axis (pass ``sharding``); tier moves are DistFlow
DMAs on real hardware, device↔host copies here.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


class OutOfPagesError(RuntimeError):
    pass


@dataclass
class PageRef:
    """ref_count>0 pages are pinned (shared via prefix cache); cached pages
    are retained for reuse after release and reclaimed under pressure."""
    page_id: int
    ref_count: int = 0
    cached: bool = False


class PagedKVPool:
    """Global NPU-tier KV pool for the attention layers of one engine."""

    def __init__(self, cfg: ModelConfig, n_pages: int, page_size: int,
                 dtype=jnp.float32, sharding=None):
        from repro.models.serving import attn_layer_count
        self.cfg = cfg
        self.n_layers = attn_layer_count(cfg)
        self.page_size = page_size
        self.n_pages = n_pages
        self.sharding = sharding                 # NamedSharding over (…,Hkv,…)
        shape = (max(self.n_layers, 1), n_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
        if sharding is not None:
            self.k = jax.device_put(jnp.zeros(shape, dtype), sharding)
            self.v = jax.device_put(jnp.zeros(shape, dtype), sharding)
        else:
            self.k = jnp.zeros(shape, dtype)
            self.v = jnp.zeros(shape, dtype)
        self._free: List[int] = list(range(n_pages))
        self._refs: Dict[int, PageRef] = {}
        # DRAM tier: handle -> (k_np, v_np) of shape (L, NP_run, P, Hkv, hd)
        self.dram: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self._dram_next = 0

    # ------------------------------------------------------------- alloc
    def free_page_count(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> List[int]:
        if len(self._free) < n:
            raise OutOfPagesError(f"need {n} pages, have {len(self._free)}")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._refs[p] = PageRef(p, ref_count=1)
        return pages

    def retain(self, pages: List[int]) -> None:
        for p in pages:
            self._refs[p].ref_count += 1

    def release(self, pages: List[int], keep_cached: bool = False) -> List[int]:
        """Drop a reference; zero-ref pages are kept cached (evictable) or
        returned to the free list. Returns freed page ids."""
        freed = []
        for p in pages:
            ref = self._refs[p]
            ref.ref_count -= 1
            if ref.ref_count <= 0:
                if keep_cached:
                    ref.cached = True
                    ref.ref_count = 0
                else:
                    del self._refs[p]
                    self._free.append(p)
                    freed.append(p)
        return freed

    def evict_cached(self, pages: List[int]) -> None:
        for p in pages:
            ref = self._refs.get(p)
            if ref is not None and ref.cached and ref.ref_count == 0:
                del self._refs[p]
                self._free.append(p)

    def reclaimable(self) -> List[int]:
        return [p for p, r in self._refs.items() if r.cached and r.ref_count == 0]

    # ------------------------------------------------------------- data
    def write_run(self, pages: List[int], offset: int,
                  k_new: jax.Array, v_new: jax.Array) -> None:
        """Write a token run into (pages, offset). k_new/v_new:
        (L, T, Hkv, hd) — all layers at once."""
        t = k_new.shape[1]
        ps = self.page_size
        flat = offset + np.arange(t)
        page_idx = jnp.asarray([pages[i // ps] for i in flat], jnp.int32)
        slot_idx = jnp.asarray(flat % ps, jnp.int32)
        self.k = self.k.at[:, page_idx, slot_idx].set(k_new)
        self.v = self.v.at[:, page_idx, slot_idx].set(v_new)

    def gather(self, pages: List[int]) -> Tuple[jax.Array, jax.Array]:
        idx = jnp.asarray(pages, jnp.int32)
        return self.k[:, idx], self.v[:, idx]       # (L, NP_run, P, Hkv, hd)

    # ------------------------------------------------------------- tiers
    def copy_to_dram(self, pages: List[int]) -> int:
        """RTC `Copy`: NPU → DRAM. Returns a DRAM handle."""
        idx = jnp.asarray(pages, jnp.int32)
        k_np = np.asarray(self.k[:, idx])
        v_np = np.asarray(self.v[:, idx])
        handle = self._dram_next
        self._dram_next += 1
        self.dram[handle] = (k_np, v_np)
        return handle

    def populate_from_dram(self, handle: int, pages: List[int]) -> None:
        """RTC `Populate` data plane: DRAM → NPU into allocated pages."""
        k_np, v_np = self.dram[handle]
        idx = jnp.asarray(pages, jnp.int32)
        self.k = self.k.at[:, idx].set(jnp.asarray(k_np[:, :len(pages)]))
        self.v = self.v.at[:, idx].set(jnp.asarray(v_np[:, :len(pages)]))

    def dram_bytes(self, handle: int) -> int:
        k_np, v_np = self.dram[handle]
        return k_np.nbytes + v_np.nbytes

    def drop_dram(self, handle: int) -> None:
        self.dram.pop(handle, None)

    def pool_bytes(self) -> int:
        return int(np.prod(self.k.shape)) * self.k.dtype.itemsize * 2


def pages_needed(n_tokens: int, page_size: int) -> int:
    return (n_tokens + page_size - 1) // page_size
