"""FLOWSERVE — the serving engine (§4). One engine == one model-serving TE.

Master–executor architecture: the master (this class) runs the scheduler,
RTC index, and DistFlow decisions; the executor side is the model runner
(+ page pools), which with ``EngineConfig.tp > 1`` IS an SPMD program
spanning the TE's NPUs — a 1×tp ("data","model") mesh with weights, paged
KV pools and slot caches sharded per launch/sharding.py (DESIGN.md §5).
Modes mirror §4.5: "colocated" (chunked-prefill + decode in one engine),
"prefill" (P-only TE) and "decode" (D-only TE) for PD-disaggregated groups.
"""
from __future__ import annotations

import functools
import itertools
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.engine.distflow import (BufferInfo, DistFlow, TransferFault,
                                   _nbytes)
from repro.engine.hotloop import DecodeHotState, pow2_bucket, pow2s
from repro.engine.kv_cache import OutOfPagesError, PagedKVPool, pages_needed
from repro.engine.runners import SequenceState, resolve_family
from repro.engine.rtc import RelationalTensorCache, RTCCostModel
from repro.engine.sampling import SamplingParams, sample_batch
from repro.engine.scheduler import Scheduler, SchedulerConfig
from repro.engine.tokenizer import EOS_ID, ByteTokenizer
from repro.models.model_factory import ModelBundle

_req_ids = itertools.count()


@dataclass
class Request:
    prompt_tokens: List[int]
    sampling: SamplingParams = field(default_factory=SamplingParams)
    req_id: str = ""
    ctx_id: Optional[str] = None        # explicit context-caching id
    arrival: float = field(default_factory=time.monotonic)
    extra: Dict[str, Any] = field(default_factory=dict)  # modality stubs

    def __post_init__(self):
        if not self.req_id:
            self.req_id = f"req-{next(_req_ids)}"


@dataclass
class Completion:
    req_id: str
    tokens: List[int]
    ttft: float
    finish: float
    arrival: float
    n_prompt: int

    @property
    def tpot(self) -> float:
        n = max(len(self.tokens) - 1, 1)
        return (self.finish - self.arrival - self.ttft) / n

    @property
    def jct(self) -> float:
        return self.finish - self.arrival


@dataclass
class EngineConfig:
    mode: str = "colocated"             # colocated | prefill | decode
    tp: int = 1                         # model-axis width of the TE's mesh
    device_offset: int = 0              # first device of the TE's 1×tp window
    n_pages: int = 256
    page_size: int = 16
    n_slots: int = 8                    # SlotRunner slots
    max_len: int = 256                  # SlotRunner per-slot capacity
    max_batch_tokens: int = 64
    max_decode_batch: int = 8
    chunk_size: int = 16
    max_prefill_seqs: int = 8           # concurrent mid-prefill sequences
    enable_prefix_cache: bool = True
    async_sched: bool = True
    fused_decode: bool = True           # NPU-centric hot loop (DESIGN.md §8)
    decode_horizon: int = 8             # max fused multi-step K (1 = off)
    batched_prefill: bool = True        # one-dispatch ragged prefill (§12)
    dtype: Any = jnp.float32
    seed: int = 0


def _executor_safe(fn):
    """Serialize an engine entry point on the per-engine RLock: the fleet
    runtime (core/fleet.py) steps TEs from per-unit worker threads while
    the JE driver thread runs cross-unit actions (drain migration, NPU-fork,
    load reads) — every public mutation must hold the engine's lock. The
    RLock keeps internal reentrancy (step → export → release) free."""
    @functools.wraps(fn)
    def wrapper(self, *args, **kwargs):
        with self._lock:
            return fn(self, *args, **kwargs)
    return wrapper


class FlowServe:
    def __init__(self, bundle: ModelBundle, params, ecfg: EngineConfig,
                 name: str = "te-0"):
        self._lock = threading.RLock()   # executor-safety (DESIGN.md §9)
        self.bundle = bundle
        self.cfg: ModelConfig = bundle.cfg
        self.ecfg = ecfg
        self.name = name
        # microkernel registry (DESIGN.md §12): the family — not an if-ladder
        # here — decides pool-vs-slots, KV sharding, and runner construction
        self.family = resolve_family(self.cfg)
        self.runner_kind = self.family.name
        self.tokenizer = ByteTokenizer(max(self.cfg.vocab_size, 259))
        self.distflow = DistFlow(owner=name)
        self.fault_plan = None           # set by FaultPlan.attach (§11)
        self._key = jax.random.PRNGKey(ecfg.seed)

        # SPMD executor mesh: the TE's NPUs form a pure TP group (tp=1 keeps
        # the legacy single-device path; DP happens across TEs via the JE).
        self.mesh = None
        self.device = None
        if ecfg.tp > 1:
            from repro.launch.mesh import make_engine_mesh
            self.mesh = make_engine_mesh(ecfg.tp, offset=ecfg.device_offset)
        elif ecfg.device_offset > 0:
            # tp=1 TEs also honor their device window (DESIGN.md §9): each
            # fleet member owns ONE device, so concurrent per-TE executors
            # genuinely overlap device work instead of queueing on device 0
            self.device = jax.devices()[ecfg.device_offset
                                        % jax.device_count()]
            params = jax.device_put(params, self.device)
            self._key = jax.device_put(self._key, self.device)

        if self.family.uses_pages:
            kv_sharding = None
            if self.mesh is not None and self.family.kv_pool_sharding is not None:
                kv_sharding = self.family.kv_pool_sharding(self.cfg, self.mesh)
            self.pool = PagedKVPool(self.cfg, ecfg.n_pages, ecfg.page_size,
                                    ecfg.dtype, sharding=kv_sharding)
            if self.device is not None:
                # unpinned jits follow their operands, so homing the pool
                # (and params/key above) is all the pinning the TE needs
                self.pool.k = jax.device_put(self.pool.k, self.device)
                self.pool.v = jax.device_put(self.pool.v, self.device)
            cm = RTCCostModel(flops_per_token=2.0 * self.cfg.active_param_count())
            self.rtc = RelationalTensorCache(self.pool, cm) \
                if ecfg.enable_prefix_cache else None
            self.runner = self.family.build(bundle, params, self.pool,
                                            dtype=ecfg.dtype, mesh=self.mesh)
        else:
            self.pool = None
            self.rtc = None
            self.runner = self.family.build(bundle, params, dtype=ecfg.dtype,
                                            mesh=self.mesh, n_slots=ecfg.n_slots,
                                            max_len=ecfg.max_len)
            if self.device is not None:
                self.runner.cache = {k: jax.device_put(v, self.device)
                                     for k, v in self.runner.cache.items()}
            self._state_cache: Dict[tuple, Any] = {} if ecfg.enable_prefix_cache else None

        scfg = SchedulerConfig(max_batch_tokens=ecfg.max_batch_tokens,
                               max_decode_batch=ecfg.max_decode_batch,
                               chunk_size=ecfg.chunk_size,
                               max_prefill_seqs=ecfg.max_prefill_seqs,
                               mode=ecfg.mode)
        self.scheduler = Scheduler(scfg, self.rtc, self.family.uses_pages)
        self._seqs: Dict[str, SequenceState] = {}
        self._requests: Dict[str, Request] = {}
        self._ttft: Dict[str, float] = {}
        self._next_plan = None
        self._prefill_done_buffer: List[str] = []  # P-mode: ready to migrate
        self.steps = 0
        self.step_wall = 0.0
        self.decode_steps = 0            # decode iterations executed (B-wide)
        self.sampler_dispatches = 0      # STANDALONE dispatches spent sampling
        self.host_dispatches = 0         # device dispatches on the decode path
        self.host_syncs = 0              # blocking device→host fetches
        # prefill-side accounting (§12): dispatches counted in BOTH modes so
        # benchmarks can compare dispatches-per-prompt-token; syncs are the
        # batched path's first-token fetches (separate from decode host_syncs,
        # which tests pin to the decode path)
        self.prefill_dispatches = 0      # device dispatches on the prefill path
        self.prefill_syncs = 0           # blocking fetches on the prefill path
        self._prefill_key = None         # persistent in-dispatch sampling key
        self.sample_params: Dict[str, SamplingParams] = {}
        # decode hot loop (DESIGN.md §8): persistent device-resident batch
        # state, in-flight token blocks (fetched one horizon late), and the
        # per-sequence count of sampled-but-uncommitted tokens
        self._hot: Optional[DecodeHotState] = None
        self._inflight: deque = deque()  # (tokens_dev, [(slot, seq_id)], K)
        self._pending: Dict[str, int] = {}
        self._completed_buf: List[Completion] = []
        self._sp_cache: tuple = (None, None, None)  # batch-keyed temps/top_ps

    @property
    def jit_compiles(self) -> int:
        """Decode-path jit cache misses (bucketed keys ⇒ 0 in steady state)."""
        return getattr(self.runner, "jit_compiles", 0)

    @property
    def prefill_jit_compiles(self) -> int:
        """Prefill-path jit cache misses (0 after ``warmup_prefill``)."""
        return getattr(self.runner, "prefill_jit_compiles", 0)

    # ---------------------------------------------------------------- scaling
    @classmethod
    def fork_from(cls, source: "FlowServe", ecfg: EngineConfig,
                  name: str = "te-fork", link: str = "ici") -> "FlowServe":
        """NPU-fork (§6.3): bring up a new TE by forking weights PER-SHARD
        from a live (possibly sharded) TE onto the new TE's own mesh —
        replacing re-initialization / host reload. Each destination shard
        fills via ``jax.device_put`` from the source's resident params (the
        ICI-broadcast analogue; ``link="dcn"`` prices the scale-out
        fallback); DistFlow charges both endpoints. The new TE is linked
        into the source's peer group."""
        from repro.core.scaling import npu_fork_live
        from repro.launch.mesh import make_engine_mesh
        if getattr(source, "fault_plan", None) is not None:
            source.fault_plan.on_fork(source)
        dst_mesh = make_engine_mesh(ecfg.tp, offset=ecfg.device_offset) \
            if ecfg.tp > 1 else None
        with source._lock:   # executor-safe vs a fleet worker stepping src
            params, lr = npu_fork_live(
                source.runner.params, source.cfg, dst_mesh,
                source=source.distflow, link=link,
                dst_device=jax.devices()[ecfg.device_offset])
            te = cls(source.bundle, params, ecfg, name=name)
            source.distflow.link_cluster([te.distflow])
        te.distflow.sim_clock += lr.seconds   # the fork target observed it too
        return te

    @classmethod
    def from_warm(cls, bundle: ModelBundle, host_params, ecfg: EngineConfig,
                  name: str = "te-warm") -> "FlowServe":
        """DRAM-warm bring-up (DESIGN.md §10): construct a TE from a
        ``WarmPool``'s host-pinned params — ``device_put`` onto the TE's
        device window replaces model re-init entirely. The pool entry is
        only read, so any number of TEs can come up from one entry
        concurrently. tp>1 TEs shard through the constructor's mesh path;
        tp=1 TEs are explicitly homed here (the constructor only pins when
        ``device_offset > 0``, but warm params must land on-device even in
        window 0 or every dispatch would re-upload them).

        Entry integrity (DESIGN.md §11): the pool stores arbitrary pytrees
        keyed by name — a stale/mispointed entry would silently build a TE
        from the WRONG weights. Validate the entry's tree structure and
        leaf shapes against ``bundle`` before committing any device memory;
        mismatch raises ``WarmPoolMismatchError``."""
        from repro.core.scaling import WarmPoolMismatchError
        expected = jax.eval_shape(
            lambda k: bundle.init_params(k, jnp.float32),
            jax.random.PRNGKey(0))
        exp_tree = jax.tree_util.tree_structure(expected)
        got_tree = jax.tree_util.tree_structure(host_params)
        exp_shapes = [tuple(l.shape) for l in jax.tree_util.tree_leaves(expected)]
        got_shapes = [tuple(np.shape(l)) for l in
                      jax.tree_util.tree_leaves(host_params)]
        if exp_tree != got_tree or exp_shapes != got_shapes:
            raise WarmPoolMismatchError(
                f"warm-pool entry does not match model "
                f"{getattr(bundle.cfg, 'name', '?')!r} for TE {name}: "
                f"tree/shape mismatch (expected {len(exp_shapes)} leaves, "
                f"got {len(got_shapes)})")
        if ecfg.tp <= 1:
            dev = jax.devices()[ecfg.device_offset % jax.device_count()]
            host_params = jax.device_put(host_params, dev)
        return cls(bundle, host_params, ecfg, name=name)

    @property
    def fork_ready(self) -> bool:
        """True while this TE's params are device-resident, i.e. it can act
        as an NPU-fork source (a TE that drained its params back to the
        warm pool on release is not)."""
        return getattr(self.runner, "params", None) is not None

    @_executor_safe
    def release_params(self, to_host: bool = True):
        """Drain this TE's device-resident params back to host DRAM (the
        RELEASED → WarmPool leg of the cold-start ladder). Returns the host
        pytree (``to_host=True``) or None; either way the device copy is
        dropped and the engine stops being a fork source. Call only after
        the TE is empty — it cannot serve afterwards."""
        params = getattr(self.runner, "params", None)
        if params is None:
            return None
        host = jax.tree.map(lambda a: np.asarray(a), params) if to_host \
            else None
        self.runner.params = None
        return host

    @_executor_safe
    def cancel_queued(self) -> List[Request]:
        """Pull every not-yet-fully-prefilled sequence out of this engine
        (drain support, DESIGN.md §10): mid-PREFILL work on a draining TE
        is re-submitted to the drain destination as a token-level restart
        instead of finishing prefill locally. Returns the original
        ``Request`` objects (req_id + arrival preserved, so latency
        accounting spans the restart); their pages/slots here are freed
        without preserving prefixes."""
        out: List[Request] = []
        for seq in list(self.scheduler.queued_seqs()):
            req = self._requests.get(seq.seq_id)
            if req is None:
                continue
            self.scheduler.remove(seq)
            seq.extra.pop("_kv_pending", None)
            self.release_request(seq.seq_id, keep_prefix=False)
            out.append(req)
        return out

    # ---------------------------------------------------------------- API
    @_executor_safe
    def add_request(self, req: Request) -> str:
        seq = SequenceState(seq_id=req.req_id, tokens=list(req.prompt_tokens),
                            n_prompt=len(req.prompt_tokens), extra=dict(req.extra))
        if not seq.extra:
            seq.extra = {k: np.asarray(v) for k, v in
                         self.bundle.extra_inputs(1, self.ecfg.dtype).items()}
        self._seqs[req.req_id] = seq
        self._requests[req.req_id] = req
        self.sample_params[req.req_id] = req.sampling
        # a reused req_id may carry different sampling params: the cached
        # per-batch temps/top_ps arrays would alias the old request's —
        # and a stale TTFT stamp would suppress re-stamping for the new one
        self._sp_cache = (None, None, None)
        self._ttft.pop(req.req_id, None)
        if self.runner_kind == "slot" and self._state_cache is not None:
            self._try_state_reuse(seq)
        self.scheduler.admit(seq)
        return req.req_id

    @_executor_safe
    def has_work(self) -> bool:
        return bool(self._inflight or self._completed_buf) \
            or self.scheduler.has_work()

    @_executor_safe
    def step(self) -> List[Completion]:
        """One engine iteration: (maybe prepared) plan → execute → sample →
        commit → prepare next plan (async mode prepares before sampling).
        With ``fused_decode`` a pure-decode step is ONE fused device
        dispatch covering a K-step horizon; its token block is fetched a
        horizon later, so completions surface with at most one extra step
        of latency (DESIGN.md §8)."""
        t0 = time.monotonic()
        if self.fault_plan is not None:
            self.fault_plan.on_step(self)
        self.scheduler.resolve_prefix()
        self.scheduler.pump_prefetch()
        plan = self._next_plan if (self.ecfg.async_sched and self._next_plan) \
            else self.scheduler.prepare_next()
        self._next_plan = None
        completions: List[Completion] = []
        if self._inflight and (plan.prefill or not plan.decode):
            # prefill page allocation may preempt a running (in-flight) seq —
            # make host state authoritative before that can happen. And when
            # the plan has NO decode batch (e.g. every sequence EOS-stopped
            # in the previous block), the orphaned in-flight horizon must be
            # committed here or nothing ever would.
            self._drain_inflight()

        # ---------------- prefill chunks
        if plan.prefill:
            if self.family.uses_pages and self.ecfg.batched_prefill:
                self._prefill_batched(plan.prefill)
            else:
                self._prefill_legacy(plan.prefill)

        # ---------------- decode batch
        if plan.decode:
            # drop seqs that finished or were preempted (requeued) after the
            # plan was (asynchronously) prepared
            live = self._refilter(plan.decode)
            fused = False
            if live and self.runner_kind == "paged" and self.ecfg.fused_decode:
                fused = self._decode_fused_step(live)
            elif live and self.runner_kind == "slot" and self.ecfg.fused_decode:
                fused = self._decode_slot_fused(live)
            if not fused and live:
                self._drain_inflight()
                live = self._refilter(live)
            if not fused and live and self.runner_kind == "paged":
                for s in live:
                    if s in self.scheduler.running:  # not yet preempted
                        self._ensure_pages(s, len(s.tokens))
                # page pressure may have preempted batch members: they must
                # NOT decode this step (their freed pages may already belong
                # to another sequence — writing would corrupt it)
                live = [s for s in live if s in self.scheduler.running]
            if not fused and live:
                for s in live:
                    handle = s.extra.pop("_kv_pending", None)
                    if handle is not None:   # first decode of a migrated seq
                        self._import_layerwise(handle, s)
                logits = self.runner.decode(live)
                self.decode_steps += 1
                self.host_dispatches += 1
                # async scheduling: the next plan depends only on counts —
                # prepare it *before* sampling commits token values (§4.2)
                if self.ecfg.async_sched:
                    self._next_plan = self.scheduler.prepare_next()
                self._commit_tokens(live, logits)
                if self._hot is not None:
                    self._hot.reset()   # device rows are stale vs host now

        if self.ecfg.async_sched and self._next_plan is None:
            self._next_plan = self.scheduler.prepare_next()
        self.steps += 1
        self.step_wall += time.monotonic() - t0
        completions.extend(self._flush_completed())
        return completions

    def run_to_completion(self, max_steps: int = 10000) -> List[Completion]:
        out = []
        for _ in range(max_steps):
            if not self.has_work():
                break
            out.extend(self.step())
        return out

    # ------------------------------------------------------- prefill paths
    def _prefill_legacy(self, entries) -> None:
        """Per-sequence prefill (the pre-§12 path, kept behind
        ``batched_prefill=False`` for parity testing; also the slot family's
        path): one batch-1 dispatch per sequence per chunk."""
        for seq, start, chunk in entries:
            if seq.n_cached != start or seq.seq_id not in self._seqs:
                continue  # stale plan entry (seq preempted/finished)
            if self.family.uses_pages:
                if chunk:
                    self._ensure_pages(seq, seq.n_cached + len(chunk))
                    self.runner.prefill_chunk(seq, chunk)
                    self.prefill_dispatches += 1
            else:
                if seq.slot is None:
                    if not self.runner.alloc_slot(seq):
                        self.scheduler.ready.appendleft(seq)  # no slot; retry
                        if seq in self.scheduler.prefilling:
                            self.scheduler.prefilling.remove(seq)
                        continue
                    snap_key = seq.extra.pop("_state_restore", None)
                    if snap_key is not None:
                        self.runner.restore_state(seq, self._state_cache[snap_key])
                if chunk:
                    self.runner.prefill_chunk(seq, chunk)
                    self.prefill_dispatches += 1
            done = seq.n_cached >= len(seq.tokens) - 1
            if done:
                self._on_prefill_done(seq)
                self.scheduler.on_prefill_progress(seq, True)
            else:
                self.scheduler.on_prefill_progress(seq, False)

    def _prefill_batched(self, entries) -> None:
        """Batched ragged prefill (the §12 tentpole): pack EVERY planned
        chunk — all sequences, ragged lengths — into ONE padded pow2-bucketed
        dispatch of the prefill microkernel. A chunk that reaches
        ``n_prompt - 1`` also takes the LAST prompt token as an extension
        row, so the prompt's first generated token is sampled inside this
        same dispatch (after it the sequence satisfies the decode invariant
        ``n_cached == len(tokens) - 1`` exactly like a first decode step had
        run). Padding tokens park on the pool's scratch page at position 0,
        attending only to their own garbage slot."""
        ps = self.ecfg.page_size
        todo = []
        for seq, start, chunk in entries:
            if seq.n_cached != start or seq.seq_id not in self._seqs:
                continue  # stale plan entry (seq preempted/finished)
            if not chunk:
                # single-token prompt or fully prefix-cached: prefill is
                # vacuously done; run the done-transition
                done = seq.n_cached >= len(seq.tokens) - 1
                if done:
                    self._on_prefill_done(seq)
                self.scheduler.on_prefill_progress(seq, done)
                continue
            ext = (self.ecfg.mode != "prefill"
                   and len(seq.tokens) == seq.n_prompt
                   and start + len(chunk) == seq.n_prompt - 1)
            todo.append((seq, start, list(chunk), ext))
        if not todo:
            return
        for seq, start, chunk, ext in todo:
            self._ensure_pages(seq, start + len(chunk) + (1 if ext else 0))
        packed = []
        for seq, start, chunk, ext in todo:
            # a later entry's page allocation may have PREEMPTED an earlier
            # one (pages released, n_cached reset) — the legacy loop catches
            # that per-entry, the batched pack must re-validate before
            # freezing indices; dropped entries are simply re-planned
            if (seq.seq_id not in self._seqs or seq.n_cached != start
                    or len(seq.pages) * ps
                    < start + len(chunk) + (1 if ext else 0)):
                continue
            packed.append((seq, start, chunk, ext))
        if not packed:
            return
        try:
            scratch = self.pool.scratch_page()
        except OutOfPagesError:
            self._prefill_legacy([(s, st, ch) for s, st, ch, _ in packed])
            return

        # ---- pack the flat ragged token stream (host-side, numpy)
        sb = pow2_bucket(max(self.ecfg.max_prefill_seqs, len(packed)))
        pb = pow2_bucket(max(len(s.pages) for s, _, _, _ in packed))
        flat_t, flat_p, flat_pg, flat_sl, rows = [], [], [], [], []
        final_idx = np.zeros((sb,), np.int32)
        temps = np.zeros((sb,), np.float32)
        top_ps = np.ones((sb,), np.float32)
        for i, (seq, start, chunk, ext) in enumerate(packed):
            toks = chunk + ([seq.tokens[-1]] if ext else [])
            row = seq.pages + [scratch] * (pb - len(seq.pages))
            for j, t in enumerate(toks):
                pos = start + j
                flat_t.append(t)
                flat_p.append(pos)
                flat_pg.append(seq.pages[pos // ps])
                flat_sl.append(pos % ps)
                rows.append(row)
            final_idx[i] = len(flat_t) - 1
            if ext:
                sp = self.sample_params[seq.seq_id]
                temps[i] = sp.temperature
                top_ps[i] = sp.top_p
        tb = pow2_bucket(len(flat_t))
        pad_row = [scratch] * pb
        while len(flat_t) < tb:
            flat_t.append(0)
            flat_p.append(0)
            flat_pg.append(scratch)
            flat_sl.append(0)
            rows.append(pad_row)

        if self._prefill_key is None:
            self._key, self._prefill_key = jax.random.split(self._key)
        _, toks_dev, self._prefill_key = self.runner.prefill_ragged(
            jnp.asarray(np.asarray(flat_t, np.int32)),
            jnp.asarray(np.asarray(flat_p, np.int32)),
            jnp.asarray(np.asarray(flat_pg, np.int32)),
            jnp.asarray(np.asarray(flat_sl, np.int32)),
            jnp.asarray(np.asarray(rows, np.int32)),
            jnp.asarray(final_idx), jnp.asarray(temps), jnp.asarray(top_ps),
            self._prefill_key)
        self.prefill_dispatches += 1

        # ---- commit: lengths, extension first-tokens, queue transitions
        toks = None
        if any(ext for _, _, _, ext in packed):
            toks = np.asarray(toks_dev)
            self.prefill_syncs += 1
        for i, (seq, start, chunk, ext) in enumerate(packed):
            seq.n_cached = start + len(chunk) + (1 if ext else 0)
            if not ext:
                done = seq.n_cached >= len(seq.tokens) - 1
                if done:
                    self._on_prefill_done(seq)
                self.scheduler.on_prefill_progress(seq, done)
                continue
            tok = int(toks[i])
            seq.tokens.append(tok)
            if self._ttft.get(seq.seq_id, 0.0) == 0.0:
                self._ttft[seq.seq_id] = (time.monotonic()
                                          - self._requests[seq.seq_id].arrival)
            self.scheduler.on_prefill_progress(seq, True)
            sp = self.sample_params[seq.seq_id]
            n_new = len(seq.tokens) - seq.n_prompt
            if (sp.stop_on_eos and tok == EOS_ID) or n_new >= sp.max_new_tokens:
                req = self._requests[seq.seq_id]
                self._completed_buf.append(Completion(
                    req_id=seq.seq_id, tokens=seq.tokens[seq.n_prompt:],
                    ttft=self._ttft[seq.seq_id], finish=time.monotonic(),
                    arrival=req.arrival, n_prompt=seq.n_prompt))
                self.scheduler.on_finished(seq)
                self.release_request(seq.seq_id)

    # ------------------------------------------------------- decode hot loop
    def warmup_decode(self, max_pages: Optional[int] = None,
                      horizons: Optional[List[int]] = None) -> int:
        """Precompile the bucketed fused decode jits (the warmup pass of
        DESIGN.md §8): every power-of-two batch bucket up to
        ``max_decode_batch`` × every page bucket up to ``max_pages`` × every
        power-of-two horizon up to ``decode_horizon``. Serving stays
        recompile-free only for sequences within ``max_pages`` pages — pass
        your workload's per-sequence worst case. The default (an even pool
        split across the decode batch) keeps the grid affordable but a
        single long sequence may exceed it and compile its bigger page
        bucket on first growth. Returns the number of executables
        compiled."""
        if self.runner_kind != "paged" or not self.ecfg.fused_decode:
            return 0
        if max_pages is None:
            max_pages = max(1, self.ecfg.n_pages
                            // max(1, self.ecfg.max_decode_batch))
        return self.runner.warmup_fused(
            pow2s(self.ecfg.max_decode_batch), pow2s(max_pages),
            horizons if horizons is not None
            else pow2s(self.ecfg.decode_horizon))

    def warmup_prefill(self, max_tokens: Optional[int] = None,
                       max_pages: Optional[int] = None) -> int:
        """Precompile the batched ragged prefill jit grid (the prefill twin
        of ``warmup_decode``, DESIGN.md §12): every pow2 token bucket up to
        the step budget — plus one extension token per prompt row — × every
        pow2 page bucket up to ``max_pages``. Serving stays recompile-free
        for sequences within ``max_pages`` pages (same caveat as
        ``warmup_decode``). Returns the number of executables compiled."""
        if self.runner_kind != "paged" or not self.ecfg.batched_prefill:
            return 0
        if max_pages is None:
            max_pages = max(1, self.ecfg.n_pages
                            // max(1, self.ecfg.max_decode_batch))
        cap = ((max_tokens if max_tokens is not None
                else self.ecfg.max_batch_tokens)
               + self.ecfg.max_prefill_seqs)
        return self.runner.warmup_ragged(
            pow2s(cap), pow2s(max_pages),
            pow2_bucket(self.ecfg.max_prefill_seqs))

    def _refilter(self, seqs: List[SequenceState]) -> List[SequenceState]:
        return [s for s in seqs if s.seq_id in self._seqs
                and s in self.scheduler.running]

    def _hot_state(self) -> DecodeHotState:
        if self._hot is None:
            sharding = None
            if self.mesh is not None:
                from repro.launch.sharding import engine_decode_state_sharding
                sharding = engine_decode_state_sharding(self.mesh)
            self._key, sub = jax.random.split(self._key)
            self._hot = DecodeHotState(self.pool, sharding=sharding, key=sub)
        return self._hot

    def _decode_fused_step(self, live: List[SequenceState]) -> bool:
        """One NPU-centric decode iteration (DESIGN.md §8): sync the
        persistent device state (zero dispatches in steady state), run a
        K-step fused decode+sample horizon as ONE dispatch, and fetch the
        PREVIOUS horizon's token block — committed one horizon late so the
        fetch is asynchronous. Returns False when the fused path cannot run
        (page pressure that needs preemption); the caller falls back to the
        legacy per-step path."""
        ps = self.pool.page_size
        for _ in range(3):   # a drain restarts the attempt; converges
            if not live:
                return True
            hlen = {s.seq_id: len(s.tokens) + self._pending.get(s.seq_id, 0)
                    for s in live}
            rem = {s.seq_id: self.sample_params[s.seq_id].max_new_tokens
                   - (hlen[s.seq_id] - s.n_prompt) for s in live}
            if min(rem.values()) < 1:
                # a stop is already sitting in an uncommitted block: commit,
                # let the finish release pages, retry with the survivors
                self._drain_inflight()
                live = self._refilter(live)
                continue
            # horizon the scheduler can prove, floored to a pow2 bucket,
            # then shrunk until the page growth fits WITHOUT preemption
            k = self.scheduler.safe_horizon(live, self.ecfg.decode_horizon,
                                            min(rem.values()))
            k = 1 << (max(1, k).bit_length() - 1)
            free = self.pool.free_page_count() + len(self.pool.reclaimable())
            if self.pool._scratch < 0:
                free -= 1                  # the hot state will pin one page
            while k >= 1:
                need = sum(max(0, pages_needed(hlen[s.seq_id] + k, ps)
                               - len(s.pages)) for s in live)
                if need <= free:
                    break
                k //= 2
            if k < 1:
                self._drain_inflight()
                return False               # legacy path may preempt
            try:
                hot = self._hot_state()
                for s in live:
                    self._ensure_pages_no_preempt(s, hlen[s.seq_id] + k)
            except OutOfPagesError:
                self._drain_inflight()
                return False
            rows2 = [(s.seq_id, len(s.pages)) for s in live]
            if self._inflight and (hot.needs_rebuild(rows2)
                                   or hot.oversized(rows2)):
                # bucket regrow — or a ≥2x shrink that would otherwise pay
                # padded-row compute every step — rebuilds rows from host
                # values, which is only coherent once nothing is pending
                self._drain_inflight()
                live = self._refilter(live)
                continue
            for s in live:
                handle = s.extra.pop("_kv_pending", None)
                if handle is not None:   # first decode of a migrated seq
                    self._import_layerwise(handle, s)
            self.host_dispatches += hot.sync(
                [(s.seq_id, s.pages, len(s.tokens),
                  s.tokens[-1] if s.tokens else 0,
                  self.sample_params[s.seq_id].temperature,
                  self.sample_params[s.seq_id].top_p) for s in live],
                can_shrink=not self._inflight)
            toks = self.runner.decode_fused(hot, k)
            self.host_dispatches += 1
            self.decode_steps += k
            for s in live:
                self._pending[s.seq_id] = \
                    self._pending.get(s.seq_id, 0) + k
            self._inflight.append(
                (toks, [(hot.slot_of[s.seq_id], s.seq_id) for s in live], k))
            # async scheduling (§4.2): the next plan needs only counts
            if self.ecfg.async_sched:
                self._next_plan = self.scheduler.prepare_next()
            # fetch the PREVIOUS horizon's block — computed behind the
            # dispatch above, so the copy does not stall the device
            while len(self._inflight) > 1:
                self._commit_oldest()
            return True
        return False

    def _decode_slot_fused(self, live: List[SequenceState]) -> bool:
        """Slot-family fused decode+sample (the SlotRunner sampling unifier,
        §12 satellite): ONE dispatch runs the all-slot decode step AND
        in-dispatch sampling through ``sampling.sample_core`` — vs the
        legacy path's decode dispatch + standalone sampler dispatch. Only
        the (n_slots,) sampled-token vector crosses to host; logits never
        move. temps/top_ps are slot-indexed (the cache is live on their
        composition, like the legacy batch-keyed cache)."""
        batch_key = tuple((s.seq_id, s.slot) for s in live)
        if self._sp_cache[0] != batch_key:
            temps = np.zeros((self.ecfg.n_slots,), np.float32)
            top_ps = np.ones((self.ecfg.n_slots,), np.float32)
            for s in live:
                sp = self.sample_params[s.seq_id]
                temps[s.slot] = sp.temperature
                top_ps[s.slot] = sp.top_p
            self._sp_cache = (batch_key, temps, top_ps)
        _, temps, top_ps = self._sp_cache
        toks_dev, self._key = self.runner.decode_sample(
            live, temps, top_ps, self._key)
        self.decode_steps += 1
        self.host_dispatches += 1
        # async scheduling (§4.2): the next plan needs only counts — prepare
        # it before the blocking token fetch
        if self.ecfg.async_sched:
            self._next_plan = self.scheduler.prepare_next()
        toks = np.asarray(toks_dev)
        self.host_syncs += 1
        self._commit_sampled(live, [int(toks[s.slot]) for s in live])
        return True

    def _commit_oldest(self) -> None:
        """Materialize the oldest in-flight token block and commit it:
        append tokens, record TTFT, and finish sequences whose EOS /
        max_new_tokens stop fired (post-stop tokens — sampled because EOS is
        checked one horizon late — are discarded)."""
        toks_dev, rows, k = self._inflight.popleft()
        try:
            ready = bool(toks_dev.is_ready())
        except AttributeError:
            ready = False
        if not ready:
            self.host_syncs += 1
        toks = np.asarray(toks_dev)
        for slot, sid in rows:
            seq = self._seqs.get(sid)
            if seq is None or sid not in self._pending:
                continue   # finished by an earlier block's late EOS
            sp = self.sample_params[sid]
            stopped = False
            for j in range(k):
                tok = int(toks[j, slot])
                seq.tokens.append(tok)
                self._pending[sid] -= 1
                if self._ttft.get(sid, 0.0) == 0.0:
                    self._ttft[sid] = \
                        time.monotonic() - self._requests[sid].arrival
                n_new = len(seq.tokens) - seq.n_prompt
                if (sp.stop_on_eos and tok == EOS_ID) \
                        or n_new >= sp.max_new_tokens:
                    stopped = True
                    break
            seq.n_cached = len(seq.tokens) - 1
            if stopped:
                self._pending.pop(sid, None)
                req = self._requests[sid]
                self._completed_buf.append(Completion(
                    req_id=sid, tokens=seq.tokens[seq.n_prompt:],
                    ttft=self._ttft[sid], finish=time.monotonic(),
                    arrival=req.arrival, n_prompt=seq.n_prompt))
                self.scheduler.on_finished(seq)
                # releasing pages now is safe even with a later block in
                # flight: pool updates chain by dispatch order, and any new
                # owner of these pages writes (and masks) before it reads
                self.release_request(sid)

    def _drain_inflight(self) -> None:
        """Commit every in-flight horizon — host state becomes
        authoritative. Required before anything that reads or invalidates
        sequence state: legacy decode, preemption, rebuilds, migration."""
        while self._inflight:
            self._commit_oldest()

    def _flush_completed(self) -> List[Completion]:
        out, self._completed_buf = self._completed_buf, []
        return out

    def _ensure_pages_no_preempt(self, seq: SequenceState,
                                 n_tokens: int) -> None:
        """Fused-path page growth: evicting cached prefixes is fine (the
        RTC does that internally) but preemption is not — it would
        invalidate in-flight horizons — so pressure raises and the caller
        falls back to the legacy path."""
        need = pages_needed(n_tokens, self.pool.page_size) - len(seq.pages)
        for _ in range(max(0, need)):
            seq.pages.append(self.rtc.append_block() if self.rtc
                             else self.pool.alloc(1)[0])

    def _import_layerwise(self, handle, seq: SequenceState) -> None:
        """ROADMAP PR-2 follow-up: per-layer ready events. Each layer chunk
        is scattered into the pool the moment IT lands
        (``MigrationHandle.wait_chunk``), so a migrated sequence's first
        decode starts behind the first chunk instead of the last — the
        scatter of chunk i overlaps the wire time of chunk i+1."""
        chunks = getattr(handle, "chunks", None)
        if chunks is None:
            self.runner.import_kv(handle.wait(), seq.pages)
            return
        for i in range(len(chunks)):
            self.runner.import_kv({"chunks": [handle.wait_chunk(i)]},
                                  seq.pages)

    # ---------------------------------------------------------------- PD
    @_executor_safe
    def pop_migratable(self) -> List[str]:
        """P-mode: request ids whose prefill finished and KV is exportable."""
        out = self._prefill_done_buffer
        self._prefill_done_buffer = []
        return out

    @_executor_safe
    def migratable_running(self) -> List[str]:
        """Drain support (DESIGN.md §9 scale-in): request ids currently in
        the decode set whose state can move to another TE right now —
        fully prefilled and not still waiting on an in-flight KV import
        (those become migratable after their first decode)."""
        return [s.seq_id for s in self.scheduler.running
                if "_kv_pending" not in s.extra]

    @_executor_safe
    def export_kv(self, req_id: str, host_gather: bool = False):
        """P-mode: KV of the first n_prompt-1 tokens; the decode TE runs the
        last prompt token as its first decode step (by-req transfer, §4.5).
        Default payload is device-resident sharded arrays (DistFlow v2);
        ``host_gather=True`` keeps the v1 numpy round-trip."""
        # snapshot coherently: commit in-flight horizons so tokens/n_cached
        # (and therefore the exported page run) reflect every sampled token
        self._drain_inflight()
        seq = self._seqs[req_id]
        payload = self.runner.export_kv(seq, host_gather=host_gather) \
            if self.runner_kind == "paged" else self.runner.export_kv(seq)
        payload["req_id"] = req_id
        payload["sampling"] = self.sample_params[req_id]
        payload["arrival"] = self._requests[req_id].arrival
        # a mid-decode sequence (drain migration) already produced its first
        # token here — carry the TTFT so the destination doesn't re-stamp it
        payload["ttft"] = self._ttft.get(req_id, 0.0)
        return payload

    def migrate_out(self, req_id: str, dst: "FlowServe", overlap: bool = True,
                    layer_chunks: int = 4, host_gather: bool = False,
                    keep_prefix: bool = True) -> str:
        """Move a prefilled request's KV/state to decode TE ``dst`` over
        DistFlow and release it here (by-request PD migration, §4.5).

        Paged path (v2): sharded page runs travel device-to-device, priced
        bytes/links per parallel ICI link and resharded in flight when the
        TEs' tp differ. With ``overlap=True`` the import is asynchronous:
        ``dst`` keeps stepping its live batch while the KV chunks stream in,
        and blocks only at its first decode of the migrated sequence.
        ``host_gather=True`` forces the v1 host round-trip (benchmarks).
        Slot (recurrent-state) payloads use the v1 path: their state is
        O(pages) smaller, so the host hop is not a hot path.

        Executor-safety: both endpoints' locks are taken up front in
        canonical (name) order — a drain migrating A→B while the fleet
        steps B concurrently must not deadlock against a B→A handoff."""
        first, second = ((self, dst) if self.name <= dst.name
                         else (dst, self))
        with first._lock, second._lock:
            return self._migrate_out_locked(req_id, dst, overlap,
                                            layer_chunks, host_gather,
                                            keep_prefix)

    def _migrate_out_locked(self, req_id: str, dst: "FlowServe",
                            overlap: bool, layer_chunks: int,
                            host_gather: bool, keep_prefix: bool) -> str:
        # committing in-flight horizons may FINISH the candidate (late EOS /
        # max_new_tokens) and release it — a mid-decode drain migration must
        # treat that as "nothing left to move", not export a ghost
        self._drain_inflight()
        if req_id not in self._seqs:
            return req_id
        # a mid-decode migration (drain) leaves the scheduler's queues NOW:
        # release_request below frees pages/slots but doesn't touch queue
        # membership (finishing seqs already left via on_finished), and a
        # zombie in `running` would keep this TE's has_work true forever
        seq = self._seqs[req_id]
        was_running = seq in self.scheduler.running
        self.scheduler.remove(seq)
        payload = self.export_kv(req_id, host_gather=host_gather)
        try:
            if self.runner_kind != "paged" or host_gather:
                if host_gather and self.runner_kind == "paged":
                    # the v1 path is a genuine host round-trip: price the DtoH
                    # gather (here) and the HtoD pool rewrite (on dst) that the
                    # device-resident path never pays
                    n_kv = _nbytes([payload["k"], payload["v"]])
                    self.distflow.charge(n_kv, "pcie_dram")
                self.distflow.transfer(
                    BufferInfo(owner=self.name, tier="npu", payload=payload),
                    BufferInfo(owner=dst.name, tier="npu",
                               deliver=dst.import_request))
                if host_gather and self.runner_kind == "paged":
                    dst.distflow.charge(n_kv, "pcie_dram")
            else:
                kv = {"k": payload.pop("k"), "v": payload.pop("v")}
                handle = self.distflow.transfer_sharded(
                    kv, dst.name, dst_sharding=dst.pool.run_sharding(),
                    src_tp=self.ecfg.tp, dst_tp=dst.ecfg.tp,
                    layer_chunks=layer_chunks)
                payload["kv_handle"] = handle
                dst.import_request(payload)
                if not overlap:
                    dst.finish_pending_imports()
        except (TransferFault, OutOfPagesError):
            # the migration did not land: a TransferFault fires BEFORE any
            # delivery and an OutOfPagesError rolls the destination back
            # before committing state — either way the destination is
            # untouched, so restore this TE's authoritative state (the seq
            # left the run queue above) and let the pump retry/backoff
            # (DESIGN.md §11) instead of stranding a zombie sequence
            if was_running and req_id in self._seqs:
                self.scheduler.admit_running(seq)
            raise
        # injected source crash mid-migration: the destination already
        # imported (the sequence continues there), but this TE dies before
        # acking/cleaning up — recovery must dedupe against the survivor
        if self.fault_plan is not None:
            self.fault_plan.on_migration(self, dst.name)
        # keep_prefix=True preserves the prefill prefix in this TE's RTC so
        # later shared-prefix requests skip the recompute (§4.3)
        self.release_request(req_id, keep_prefix=keep_prefix)
        return req_id

    @_executor_safe
    def finish_pending_imports(self) -> None:
        """D-mode: synchronously drain every deferred KV import (the eager
        complement of the decode-time lazy wait)."""
        for seq in self._seqs.values():
            handle = seq.extra.pop("_kv_pending", None)
            if handle is not None:
                self._import_layerwise(handle, seq)

    @_executor_safe
    def void_pending_imports(self, dead_owners) -> List[Request]:
        """Recovery (DESIGN.md §11): void every in-flight KV import whose
        SOURCE endpoint died. The chunks may reference the dead TE's pool
        arrays, so they are never scattered — the sequence's local state is
        released and its original ``Request`` returned for a prompt-level
        restart on a survivor. Idempotent per sequence (the handle is
        popped), which is what makes recovery dedupe-safe."""
        out: List[Request] = []
        for seq in list(self._seqs.values()):
            handle = seq.extra.get("_kv_pending")
            if handle is None \
                    or getattr(handle, "src_owner", None) not in dead_owners:
                continue
            seq.extra.pop("_kv_pending", None)
            req = self._requests.get(seq.seq_id)
            self.scheduler.remove(seq)
            self.release_request(seq.seq_id, keep_prefix=False)
            if req is not None:
                out.append(req)
        return out

    @_executor_safe
    def release_request(self, req_id: str, keep_prefix: bool = True) -> None:
        seq = self._seqs.pop(req_id, None)
        self._pending.pop(req_id, None)
        if self._hot is not None:
            self._hot.evict(req_id)   # a reused id must join fresh, not alias
        if seq is None:
            return
        if self.runner_kind == "paged" and seq.pages:
            own = seq.pages[seq.reused_pages:]
            shared = seq.pages[:seq.reused_pages]
            preserve = self.rtc is not None and keep_prefix and seq.n_cached > 0
            if preserve:
                self.rtc.preserve_prefix(tuple(seq.tokens[:seq.n_cached]),
                                         seq.pages,
                                         ctx_id=self._requests[req_id].ctx_id)
            self.pool.release(own, keep_cached=preserve)
            if shared:
                self.pool.release(shared, keep_cached=True)
        elif self.runner_kind == "slot":
            if self._state_cache is not None and seq.slot is not None:
                key = tuple(seq.tokens[:seq.n_cached])
                if key and len(self._state_cache) < 32:
                    self._state_cache[key] = self.runner.snapshot_state(seq)
            self.runner.free_slot(seq)
        self._requests.pop(req_id, None)

    @_executor_safe
    def import_request(self, payload) -> str:
        """D-mode: accept a migrated (prefilled) request from a prefill TE.
        The next decode step processes the final prompt token. Drain
        migrations (DESIGN.md §9) arrive MID-decode: their tokens extend
        past the prompt and their TTFT already happened on the source TE,
        so it's seeded here instead of re-stamped at the next commit."""
        req = Request(prompt_tokens=payload["tokens"][:payload["n_prompt"]],
                      sampling=payload["sampling"], req_id=payload["req_id"])
        req.arrival = payload["arrival"]
        if (payload.get("ttft", 0.0) > 0.0
                and len(payload["tokens"]) > payload["n_prompt"]):
            self._ttft[req.req_id] = payload["ttft"]
        seq = SequenceState(seq_id=req.req_id,
                            tokens=list(payload["tokens"]),
                            n_prompt=payload["n_prompt"],
                            n_cached=payload["n_cached"])
        self._seqs[req.req_id] = seq
        self._requests[req.req_id] = req
        self.sample_params[req.req_id] = req.sampling
        self._sp_cache = (None, None, None)   # same aliasing rule as add
        if self.runner_kind == "paged":
            n_pages = payload.get("n_pages")
            if n_pages is None:
                n_pages = payload["k"].shape[1]
            # allocate through the RTC when present: cached (zero-ref)
            # prefix pages are evicted COHERENTLY with the index, so a
            # decode TE whose pool filled up with preserved prefixes can
            # still admit migrations; true pressure raises BEFORE any
            # sequence state is committed (backpressure, DESIGN.md §9)
            seq.pages = []
            try:
                for _ in range(n_pages):
                    seq.pages.append(self.rtc.append_block() if self.rtc
                                     else self.pool.alloc(1)[0])
            except OutOfPagesError:
                self.pool.release(seq.pages)
                self._seqs.pop(req.req_id, None)
                self._requests.pop(req.req_id, None)
                self.sample_params.pop(req.req_id, None)
                raise
            handle = payload.get("kv_handle")
            if handle is not None:
                # async migration (DistFlow v2): KV chunks are still in
                # flight — decode other sequences freely; the first decode
                # step touching THIS sequence waits and scatters.
                seq.extra["_kv_pending"] = handle
            else:
                self.runner.import_kv(payload, seq.pages)
        else:
            if not self.runner.alloc_slot(seq):
                # same backpressure signal as the paged path's pool.alloc —
                # callers gate migrations on destination capacity
                self._seqs.pop(req.req_id, None)
                self._requests.pop(req.req_id, None)
                self.sample_params.pop(req.req_id, None)
                raise OutOfPagesError(
                    f"decode TE {self.name} has no free slot for migrated "
                    f"request {req.req_id}")
            self.runner.import_kv(payload, seq)
        self.scheduler.admit_running(seq)
        return req.req_id

    # ---------------------------------------------------------------- internals
    def _ensure_pages(self, seq: SequenceState, n_tokens: int) -> None:
        need = pages_needed(n_tokens, self.pool.page_size) - len(seq.pages)
        for _ in range(max(0, need)):
            while True:
                try:
                    page = (self.rtc.append_block() if self.rtc
                            else self.pool.alloc(1)[0])
                    break
                except OutOfPagesError:
                    victim = self._pick_victim(exclude=seq)
                    if victim is None:
                        raise
                    self._preempt(victim)
            seq.pages.append(page)

    def _pick_victim(self, exclude: SequenceState) -> Optional[SequenceState]:
        """Most recently admitted page-holding seq (decoding, then
        mid-prefill), excluding the requester."""
        for pool in (self.scheduler.running, self.scheduler.prefilling):
            for cand in reversed(pool):
                if cand is not exclude and cand.pages:
                    return cand
        return None

    def _preempt(self, seq: SequenceState) -> None:
        # commit in-flight horizons first: the victim may have uncommitted
        # tokens, and requeue resets state the commits would corrupt
        if self._inflight:
            self._drain_inflight()
            if seq.seq_id not in self._seqs \
                    or (seq not in self.scheduler.running
                        and seq not in self.scheduler.prefilling):
                return   # the drain already finished (released) the victim
        self._pending.pop(seq.seq_id, None)
        if self._hot is not None:
            self._hot.reset()   # victim's device row must not be reused
        own = seq.pages[seq.reused_pages:]
        shared = seq.pages[:seq.reused_pages]
        self.pool.release(own)
        if shared:
            self.pool.release(shared, keep_cached=True)
        seq.reused_pages = 0
        # a not-yet-imported migration is void: its pages were just released
        # and requeue re-prefills from scratch — never scatter the stale run
        seq.extra.pop("_kv_pending", None)
        self.scheduler.requeue(seq)

    def _on_prefill_done(self, seq: SequenceState) -> None:
        """Prefill covered tokens [0, n_prompt-1); the final prompt token is
        processed by the decode path (its KV write + first-token logits),
        either locally (colocated) or on the decode TE (PD-disaggregated)."""
        if self.ecfg.mode == "prefill":
            self._prefill_done_buffer.append(seq.seq_id)
            self._ttft[seq.seq_id] = time.monotonic() - self._requests[seq.seq_id].arrival

    def _commit_tokens(self, seqs: List[SequenceState], logits) -> None:
        """Legacy (non-fused) sampling: the whole decode batch in ONE
        vmapped device dispatch (one PRNG split per step, not one fold_in
        per sequence), then commit tokens / completions on the host. The
        per-batch temperature/top_p arrays are cached keyed on the batch
        composition — join/finish/preempt changes the key, which is the
        invalidation."""
        self._key, sub = jax.random.split(self._key)
        batch_key = tuple(s.seq_id for s in seqs)
        if self._sp_cache[0] != batch_key:
            sps = [self.sample_params[sid] for sid in batch_key]
            self._sp_cache = (
                batch_key,
                np.asarray([sp.temperature for sp in sps], np.float32),
                np.asarray([sp.top_p for sp in sps], np.float32))
        _, temps, top_ps = self._sp_cache
        toks = np.asarray(sample_batch(logits, temps, top_ps, sub,
                                       self.cfg.vocab_size))
        self.sampler_dispatches += 1
        self.host_dispatches += 1
        self.host_syncs += 1             # np.asarray blocks on this step
        self._commit_sampled(seqs, [int(toks[i]) for i in range(len(seqs))])

    def _commit_sampled(self, seqs: List[SequenceState],
                        toks: List[int]) -> None:
        """Commit one freshly sampled token per sequence: append, stamp
        TTFT, and finish on EOS / max_new_tokens."""
        for seq, tok in zip(seqs, toks):
            sp = self.sample_params[seq.seq_id]
            seq.tokens.append(tok)
            if seq.seq_id not in self._ttft or self._ttft[seq.seq_id] == 0.0:
                self._ttft[seq.seq_id] = time.monotonic() - self._requests[seq.seq_id].arrival
            n_new = len(seq.tokens) - seq.n_prompt
            if (sp.stop_on_eos and tok == EOS_ID) or n_new >= sp.max_new_tokens:
                req = self._requests[seq.seq_id]
                self._completed_buf.append(Completion(
                    req_id=seq.seq_id, tokens=seq.tokens[seq.n_prompt:],
                    ttft=self._ttft[seq.seq_id], finish=time.monotonic(),
                    arrival=req.arrival, n_prompt=seq.n_prompt))
                self.scheduler.on_finished(seq)
                self.release_request(seq.seq_id)

    def _try_state_reuse(self, seq: SequenceState) -> None:
        """SSM prefix cache: longest state checkpoint whose token prefix
        matches the prompt (exact-boundary reuse, DESIGN.md §4). n_cached is
        committed now (the scheduler plans chunks from it); the snapshot is
        restored once a slot is assigned."""
        best_key, best_len = None, 0
        prompt = tuple(seq.tokens[:seq.n_prompt])
        for key in self._state_cache or {}:
            n = len(key)
            if n > best_len and n < len(prompt) and prompt[:n] == key:
                best_key, best_len = key, n
        if best_key is not None:
            seq.extra["_state_restore"] = best_key
            seq.n_cached = best_len

    # stats -------------------------------------------------------------
    def prefix_cache_stats(self) -> Dict[str, int]:
        return dict(self.rtc.stats) if self.rtc else {}

    @_executor_safe
    def load_metrics(self) -> Dict[str, float]:
        """Real load signals for the JE's live TEHandle adapter
        (DESIGN.md §9), replacing the hand-maintained floats:

        * ``queued_prefill_tokens`` — prefill tokens still owed to queued
          sequences (``Scheduler.queued_prefill_tokens``);
        * ``inflight_decode_tokens`` — remaining ``max_new_tokens`` budget
          of every sequence resident in THIS engine (queued or decoding;
          in-flight fused horizons count via ``_pending``). A PD pair's
          sequences live in exactly one endpoint at a time, so summing the
          pair never double-counts;
        * ``horizon_headroom`` — the fused multi-step horizon the scheduler
          can currently prove (§8): a TE decoding K steps per dispatch
          serves its decode budget cheaper, which the JE folds into the
          load comparison;
        * ``n_queued`` / ``n_running`` / ``occupancy`` /
          ``free_page_frac`` — queue-depth and capacity signals.
        """
        sch = self.scheduler
        decode_toks = 0
        running_rem = []
        running = set(id(s) for s in sch.running)
        for seq in self._seqs.values():
            sp = self.sample_params.get(seq.seq_id)
            if sp is None:
                continue
            produced = (max(0, len(seq.tokens) - seq.n_prompt)
                        + self._pending.get(seq.seq_id, 0))
            rem = max(0, sp.max_new_tokens - produced)
            decode_toks += rem
            if id(seq) in running:
                running_rem.append(rem)
        headroom = 1
        if (self.runner_kind == "paged" and self.ecfg.fused_decode
                and running_rem):
            # same proof the fused path runs (§8): the budget term is the
            # batch's min remaining max_new_tokens, not the horizon cap
            headroom = sch.safe_horizon(list(sch.running),
                                        self.ecfg.decode_horizon,
                                        max(1, min(running_rem)))
        return {
            "queued_prefill_tokens": float(sch.queued_prefill_tokens()),
            "inflight_decode_tokens": float(decode_toks),
            "horizon_headroom": float(max(1, headroom)),
            "n_queued": sch.queue_depth(),
            "n_running": len(sch.running),
            "occupancy": sch.occupancy(),
            "free_page_frac": (self.pool.free_page_count() / self.pool.n_pages
                               if self.pool is not None else 1.0),
        }
