"""DistFlow — §4.4: p2p / M:N tensor transfer across tiered memory and
between engines.

Control plane: ``LinkCluster`` builds peer groups (the M:N prefill↔decode
channels of §4.6). Data plane: ``transfer(src_info, dst_info)`` on raw
buffers. Backends model the two Ascend fabrics on TPU terms:
  * "ici"    — scaled-up intra-pod links (HCCS analogue), ~50 GB/s/link
  * "dcn"    — scaled-out inter-pod network (RoCE analogue), ~25 GB/s/host
  * "memcpy" — SuperPod global-shared-memory analogue (host copy)
Transfers move real numpy/JAX buffers in-process and charge transfer time
on a simulated clock so cluster-scale benchmarks (Figures 10/11) read the
same code path the engine uses.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

BACKENDS = {
    "ici": {"bw": 50e9, "lat": 1e-6},
    "dcn": {"bw": 25e9, "lat": 10e-6},
    "memcpy": {"bw": 400e9, "lat": 0.5e-6},
    "pcie_dram": {"bw": 25e9, "lat": 5e-6},
    "ssd": {"bw": 3e9, "lat": 100e-6},
}

_xfer_ids = itertools.count()


@dataclass
class BufferInfo:
    """src/dst descriptor: owner engine id, memory tier, opaque buffer."""
    owner: str
    tier: str                      # "npu" | "dram" | "ssd"
    payload: Any = None            # ndarray / pytree (src side)
    deliver: Optional[Callable[[Any], None]] = None  # dst side sink


@dataclass
class Transfer:
    xfer_id: int
    n_bytes: int
    backend: str
    sim_seconds: float
    wall_seconds: float
    done: bool = True


def _nbytes(x) -> int:
    import jax
    leaves = jax.tree.leaves(x)
    return int(sum(np.asarray(l).nbytes for l in leaves))


class DistFlow:
    """One DistFlow endpoint per executor; a shared registry links peers."""

    def __init__(self, owner: str, default_backend: str = "ici"):
        self.owner = owner
        self.default_backend = default_backend
        self.peers: Dict[str, "DistFlow"] = {}
        self.log: List[Transfer] = []
        self.sim_clock = 0.0

    # -------------------------------------------------------- control
    def link_cluster(self, peers: List["DistFlow"]) -> None:
        """LinkCluster: establish an M:N peer group (symmetric)."""
        for p in peers:
            if p.owner == self.owner:
                continue
            self.peers[p.owner] = p
            p.peers[self.owner] = self

    # -------------------------------------------------------- data
    def transfer(self, src: BufferInfo, dst: BufferInfo,
                 backend: Optional[str] = None) -> Transfer:
        """Synchronous-completion transfer of src.payload to dst.deliver.
        Charges simulated time by backend bandwidth/latency."""
        backend = backend or self._pick_backend(src, dst)
        spec = BACKENDS[backend]
        t0 = time.monotonic()
        payload = src.payload
        if dst.deliver is not None:
            dst.deliver(payload)
        n = _nbytes(payload)
        sim = spec["lat"] + n / spec["bw"]
        self.sim_clock += sim
        xfer = Transfer(next(_xfer_ids), n, backend, sim, time.monotonic() - t0)
        self.log.append(xfer)
        return xfer

    def broadcast(self, src: BufferInfo, dsts: List[BufferInfo],
                  backend: Optional[str] = None) -> List[Transfer]:
        """One-to-many transfer (HCCL-broadcast analogue used by NPU-fork,
        §6.2). Simulated time is a single traversal (tree broadcast) rather
        than N sequential sends."""
        backend = backend or self.default_backend
        spec = BACKENDS[backend]
        out = []
        n = _nbytes(src.payload)
        for d in dsts:
            if d.deliver is not None:
                d.deliver(src.payload)
            out.append(Transfer(next(_xfer_ids), n, backend, 0.0, 0.0))
        import math
        fanout_penalty = 1.0 + 0.1 * max(0, math.ceil(math.log2(max(len(dsts), 1))))
        sim = spec["lat"] + (n / spec["bw"]) * fanout_penalty
        self.sim_clock += sim
        for o in out:
            o.sim_seconds = sim
        return out

    def _pick_backend(self, src: BufferInfo, dst: BufferInfo) -> str:
        if src.tier == "dram" and dst.tier == "npu":
            return "pcie_dram"
        if src.tier == "npu" and dst.tier == "dram":
            return "pcie_dram"
        if src.tier == "ssd" or dst.tier == "ssd":
            return "ssd"
        if src.owner == dst.owner:
            return "memcpy"
        return self.default_backend

    # -------------------------------------------------------- stats
    def bytes_moved(self) -> int:
        return sum(t.n_bytes for t in self.log)
