"""DistFlow — §4.4: p2p / M:N tensor transfer across tiered memory and
between engines.

Control plane: ``LinkCluster`` builds peer groups (the M:N prefill↔decode
channels of §4.6). Data plane: ``transfer(src_info, dst_info)`` on raw
buffers and — the v2 path — ``transfer_sharded`` on device-resident
``jax.Array`` payloads that never round-trip through the host. Backends
model the two Ascend fabrics on TPU terms:
  * "ici"    — scaled-up intra-pod links (HCCS analogue), ~50 GB/s/link
  * "dcn"    — scaled-out inter-pod network (RoCE analogue), ~25 GB/s/host
  * "memcpy" — SuperPod global-shared-memory analogue (host copy)
Transfers move real numpy/JAX buffers in-process and charge transfer time
on a simulated clock so cluster-scale benchmarks (Figures 10/11) read the
same code path the engine uses. Both endpoints of a transfer observe the
elapsed time: the initiator's clock AND the linked peer's clock advance.

DistFlow v2 (DESIGN.md §7): a sharded transfer moves per-shard page runs
"device-to-device". With `links` parallel ICI links between the endpoint
TEs (one per shard pair, links = min(src_tp, dst_tp)), each link carries
``n_bytes/links``, so wire time is ``n/(links·bw)``; DCN is a per-host
fallback priced over a single link. When the endpoints' tp differ, the
payload is resharded in flight via ``jax.device_put`` to the destination
mesh's sharding. Transfers are layer-chunked: each chunk's device_put is
dispatched asynchronously, and the returned ``MigrationHandle`` blocks
only at ``wait()`` — a decode TE keeps stepping while KV streams in.
The steady-state driver is the serving plane's per-step PD-pair pump
(``ServingJobEngine.step`` → ``migrate_out``, DESIGN.md §9).
"""
from __future__ import annotations

import itertools
import math
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

BACKENDS = {
    "ici": {"bw": 50e9, "lat": 1e-6},
    "dcn": {"bw": 25e9, "lat": 10e-6},
    "memcpy": {"bw": 400e9, "lat": 0.5e-6},
    "pcie_dram": {"bw": 25e9, "lat": 5e-6},
    "ssd": {"bw": 3e9, "lat": 100e-6},
}

_xfer_ids = itertools.count()


class TransferFault(RuntimeError):
    """Transient wire failure: the transfer did NOT happen (no bytes
    charged, nothing delivered). Callers retry with backoff; the migration
    pump restores both endpoints' request state first (core/faults.py).
    Defined here — not in ``repro.core.faults`` — because the engine layer
    cannot import ``repro.core`` (circular import via core/__init__)."""


@dataclass
class BufferInfo:
    """src/dst descriptor: owner engine id, memory tier, opaque buffer."""
    owner: str
    tier: str                      # "npu" | "dram" | "ssd"
    payload: Any = None            # ndarray / pytree (src side)
    deliver: Optional[Callable[[Any], None]] = None  # dst side sink


@dataclass
class Transfer:
    xfer_id: int
    n_bytes: int
    backend: str
    sim_seconds: float
    wall_seconds: float
    done: bool = True
    links: int = 1                 # parallel fabric links priced (v2 sharded)


@dataclass
class MigrationHandle:
    """Async sharded-KV migration. The chunk device_puts were already
    dispatched (jax async dispatch), so the source is free immediately.

    Completion is exposed at two granularities:
      * ``wait()`` — block until EVERY chunk has landed; returns the
        scatter-ready payload ``{"chunks": [(layer_start, k, v), ...]}``.
      * ``wait_chunk(i)`` / ``chunk_ready(i)`` — per-layer ready events
        (ROADMAP PR-2 follow-up): an importer can scatter each layer chunk
        the moment IT lands, starting the migrated sequence's first decode
        behind the FIRST chunk instead of the last. ``xfer.done`` flips
        once the last outstanding chunk has been consumed either way.
    """
    xfer: Transfer
    chunks: List[Tuple[int, Any, Any]]
    landed: List[bool] = None   # per-chunk ready events
    src_owner: str = ""         # endpoints, for voiding on endpoint death
    dst_owner: str = ""

    def __post_init__(self):
        if self.landed is None:
            self.landed = [False] * len(self.chunks)

    def wait_chunk(self, i: int) -> Tuple[int, Any, Any]:
        """Block until chunk ``i`` (one contiguous layer slice) has landed
        on the destination devices; returns that chunk alone."""
        import jax
        _, kc, vc = self.chunks[i]
        jax.block_until_ready(kc)
        jax.block_until_ready(vc)
        self.landed[i] = True
        if all(self.landed):
            self.xfer.done = True
        return self.chunks[i]

    def chunk_ready(self, i: int) -> bool:
        """Non-blocking per-layer ready probe."""
        if self.landed[i]:
            return True
        _, kc, vc = self.chunks[i]
        try:
            ready = bool(kc.is_ready() and vc.is_ready())
        except AttributeError:      # plain ndarray payloads are always ready
            ready = True
        if ready:
            self.landed[i] = True
            if all(self.landed):
                self.xfer.done = True
        return ready

    def wait(self) -> Dict[str, Any]:
        for i in range(len(self.chunks)):
            self.wait_chunk(i)
        return {"chunks": self.chunks}

    @property
    def n_bytes(self) -> int:
        return self.xfer.n_bytes


def _nbytes(x) -> int:
    import jax
    total = 0
    for leaf in jax.tree.leaves(x):
        nb = getattr(leaf, "nbytes", None)   # jax.Array/ndarray: no host copy
        total += int(nb) if nb is not None else int(np.asarray(leaf).nbytes)
    return total


def _fanout_penalty(n_dsts: int) -> float:
    """Tree-broadcast depth penalty (HCCL-broadcast analogue)."""
    return 1.0 + 0.1 * max(0, math.ceil(math.log2(max(n_dsts, 1))))


class DistFlow:
    """One DistFlow endpoint per executor; a shared registry links peers."""

    def __init__(self, owner: str, default_backend: str = "ici"):
        self.owner = owner
        self.default_backend = default_backend
        self.peers: Dict[str, "DistFlow"] = {}
        self.log: List[Transfer] = []
        self.sim_clock = 0.0
        # fault-injection hook (src_owner, dst_owner, n_bytes) -> None,
        # raising TransferFault BEFORE any bytes move (core/faults.py)
        self.fault_hook: Optional[Callable[[str, str, int], None]] = None

    # -------------------------------------------------------- control
    def link_cluster(self, peers: List["DistFlow"]) -> None:
        """LinkCluster: establish an M:N peer group (symmetric)."""
        for p in peers:
            if p.owner == self.owner:
                continue
            self.peers[p.owner] = p
            p.peers[self.owner] = self

    # -------------------------------------------------------- accounting
    def charge(self, n_bytes: int, backend: str, *, links: int = 1,
               fanout: float = 1.0, peer_owners: Tuple[str, ...] = (),
               wall: float = 0.0, done: bool = True) -> Transfer:
        """Price a transfer and advance BOTH endpoints' clocks: the
        initiator and every linked peer observe the elapsed fabric time.
        Latency is charged once — chunked/streamed payloads pipeline each
        chunk's launch latency behind its predecessor's wire time."""
        spec = BACKENDS[backend]
        links = max(1, links)
        sim = spec["lat"] + (n_bytes / links / spec["bw"]) * fanout
        self.sim_clock += sim
        for owner in set(peer_owners):
            peer = self.peers.get(owner)
            if peer is not None and peer is not self:
                peer.sim_clock += sim
        xfer = Transfer(next(_xfer_ids), n_bytes, backend, sim, wall,
                        done=done, links=links)
        self.log.append(xfer)
        return xfer

    # -------------------------------------------------------- data (v1)
    def transfer(self, src: BufferInfo, dst: BufferInfo,
                 backend: Optional[str] = None) -> Transfer:
        """Synchronous-completion transfer of src.payload to dst.deliver.
        Charges simulated time by backend bandwidth/latency."""
        backend = backend or self._pick_backend(src, dst)
        if self.fault_hook is not None:
            self.fault_hook(src.owner, dst.owner, _nbytes(src.payload))
        t0 = time.monotonic()
        payload = src.payload
        if dst.deliver is not None:
            dst.deliver(payload)
        return self.charge(_nbytes(payload), backend,
                           peer_owners=(dst.owner,),
                           wall=time.monotonic() - t0)

    def broadcast(self, src: BufferInfo, dsts: List[BufferInfo],
                  backend: Optional[str] = None) -> List[Transfer]:
        """One-to-many transfer (HCCL-broadcast analogue used by NPU-fork,
        §6.2). Simulated time is a single traversal (tree broadcast) rather
        than N sequential sends; every destination's clock advances by it."""
        backend = backend or self.default_backend
        spec = BACKENDS[backend]
        t0 = time.monotonic()
        n = _nbytes(src.payload)
        for d in dsts:
            if d.deliver is not None:
                d.deliver(src.payload)
        wall = time.monotonic() - t0
        sim = spec["lat"] + (n / spec["bw"]) * _fanout_penalty(len(dsts))
        self.sim_clock += sim
        out = []
        for d in dsts:
            peer = self.peers.get(d.owner)
            if peer is not None and peer is not self:
                peer.sim_clock += sim
            out.append(Transfer(next(_xfer_ids), n, backend, sim, wall))
        self.log.extend(out)
        return out

    # -------------------------------------------------------- data (v2)
    def transfer_sharded(self, kv: Dict[str, Any], dst_owner: str, *,
                         dst_sharding: Any = None, src_tp: int = 1,
                         dst_tp: int = 1, layer_chunks: int = 4,
                         backend: Optional[str] = None) -> MigrationHandle:
        """Device-resident shard-aware page-run transfer (DistFlow v2).

        ``kv`` holds sharded ``jax.Array`` runs ``{"k","v"}`` of shape
        (L, NP_run, P, Hkv, hd); they are split into ``layer_chunks``
        layer-contiguous chunks, each ``jax.device_put`` to ``dst_sharding``
        (the destination mesh's pool sharding — the reshard happens in
        flight when src_tp ≠ dst_tp). ICI time is priced per parallel link:
        min(src_tp, dst_tp) links each carry bytes/links. Returns an async
        ``MigrationHandle``; nothing blocks until its ``wait()``.
        """
        import jax
        backend = backend or self.default_backend
        if self.fault_hook is not None:
            self.fault_hook(self.owner, dst_owner, _nbytes([kv["k"], kv["v"]]))
        t0 = time.monotonic()
        k, v = kv["k"], kv["v"]
        n_layers = int(k.shape[0])
        step = max(1, -(-n_layers // max(1, layer_chunks)))
        chunks: List[Tuple[int, Any, Any]] = []
        for l0 in range(0, n_layers, step):
            kc = k[l0:l0 + step] if step < n_layers else k
            vc = v[l0:l0 + step] if step < n_layers else v
            if dst_sharding is not None:
                kc = jax.device_put(kc, dst_sharding)
                vc = jax.device_put(vc, dst_sharding)
            chunks.append((l0, kc, vc))
        links = max(1, min(src_tp, dst_tp)) if backend == "ici" else 1
        xfer = self.charge(_nbytes([k, v]), backend, links=links,
                           peer_owners=(dst_owner,),
                           wall=time.monotonic() - t0, done=False)
        return MigrationHandle(xfer=xfer, chunks=chunks,
                               src_owner=self.owner, dst_owner=dst_owner)

    def _pick_backend(self, src: BufferInfo, dst: BufferInfo) -> str:
        if src.tier == "dram" and dst.tier == "npu":
            return "pcie_dram"
        if src.tier == "npu" and dst.tier == "dram":
            return "pcie_dram"
        if src.tier == "ssd" or dst.tier == "ssd":
            return "ssd"
        if src.owner == dst.owner:
            return "memcpy"
        return self.default_backend

    # -------------------------------------------------------- stats
    def bytes_moved(self) -> int:
        return sum(t.n_bytes for t in self.log)
