"""FLOWSERVE's centralized master scheduler (§4.2).

Continuous batching with chunked prefill (Sarathi-style token budget per
step), preemption under page pressure, and the paper's two asynchrony
mechanisms:

  * async KV-cache prefetch — requests whose prefix matched a DRAM-tier
    RTC entry wait in PREFETCHING until the populate ticket completes
    (pumped off the critical path), then join the ready queue;
  * async (zero-overhead) execution — scheduling the next step needs only
    token *counts*, never token values, so ``prepare_next`` can run while
    the model executes the current step; the engine measures the critical
    path both ways (Figure 3's v1→v2 gap).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.engine.kv_cache import OutOfPagesError, pages_needed
from repro.engine.runners.base import SequenceState
from repro.engine.rtc import RelationalTensorCache


@dataclass
class StepPlan:
    # (seq, start_offset, chunk) — start lets the engine drop chunks that
    # became stale because the seq was preempted after planning
    prefill: List[Tuple[SequenceState, int, List[int]]] = field(default_factory=list)
    decode: List[SequenceState] = field(default_factory=list)

    def is_empty(self) -> bool:
        return not self.prefill and not self.decode


@dataclass
class SchedulerConfig:
    max_batch_tokens: int = 64          # chunked-prefill token budget / step
    max_decode_batch: int = 8
    chunk_size: int = 16                # prefill chunk granularity
    max_prefill_seqs: int = 8           # concurrent mid-prefill sequences
    mode: str = "colocated"             # colocated | prefill | decode


class Scheduler:
    """Owns the queues; the engine owns execution and page allocation."""

    def __init__(self, cfg: SchedulerConfig, rtc: Optional[RelationalTensorCache],
                 paged: bool):
        self.cfg = cfg
        self.rtc = rtc
        self.paged = paged
        self.waiting: deque = deque()           # SequenceState
        self.prefetching: List[Tuple[SequenceState, int]] = []  # (seq, ticket)
        self.ready: deque = deque()             # prefix resolved, needs prefill
        self.prefilling: List[SequenceState] = []
        self.running: List[SequenceState] = []  # decoding
        self.sched_time = 0.0                   # cumulative scheduler seconds

    # ------------------------------------------------------------ intake
    def admit(self, seq: SequenceState) -> None:
        self.waiting.append(seq)

    def resolve_prefix(self) -> None:
        """RTC match + populate decisions for newly waiting requests
        (the sched-enqueue thread of §4.2)."""
        while self.waiting:
            seq = self.waiting.popleft()
            if self.rtc is None:
                self.ready.append(seq)
                continue
            m = self.rtc.match_by_prefix_token(seq.tokens[:seq.n_prompt])
            if m.entry is None or m.matched_tokens == 0:
                self.ready.append(seq)
                continue
            if m.location == "npu":
                n, pages = self.rtc.reuse(
                    m.entry, min(m.matched_tokens, seq.n_prompt - 1))
                seq.pages = list(pages)
                seq.reused_pages = len(pages)
                seq.n_cached = n
                self.ready.append(seq)
            elif m.location == "dram":
                ticket = self.rtc.populate(m.entry)
                if ticket is None:  # cost model said recompute
                    self.ready.append(seq)
                else:
                    self.prefetching.append((seq, ticket.ticket))
            else:
                self.ready.append(seq)

    def pump_prefetch(self) -> None:
        if self.rtc is None or not self.prefetching:
            return
        self.rtc.pump_populates()
        still = []
        for seq, ticket in self.prefetching:
            if self.rtc.query_populate(ticket) or ticket not in self.rtc._pending:
                m = self.rtc.match_by_prefix_token(seq.tokens[:seq.n_prompt])
                if m.entry is not None and m.location == "npu":
                    n, pages = self.rtc.reuse(
                        m.entry, min(m.matched_tokens, seq.n_prompt - 1))
                    seq.pages = list(pages)
                    seq.reused_pages = len(pages)
                    seq.n_cached = n
                self.ready.append(seq)
            else:
                still.append((seq, ticket))
        self.prefetching = still

    # ------------------------------------------------------------ planning
    def prepare_next(self) -> StepPlan:
        """Build the next step's plan from queue *counts* only (async-safe).
        Chunked prefill: decode seqs cost 1 token each; the remaining token
        budget goes to prefill chunks."""
        t0 = time.monotonic()
        plan = StepPlan()
        if self.cfg.mode != "prefill":
            plan.decode = list(self.running[: self.cfg.max_decode_batch])
        budget = self.cfg.max_batch_tokens - len(plan.decode)
        if self.cfg.mode != "decode":
            # continue in-flight prefills first, then admit from ready
            candidates = list(self.prefilling)
            while self.ready and len(candidates) < self.cfg.max_prefill_seqs:
                candidates.append(self.ready.popleft())
            for seq in candidates:
                # target = every token but the last (which the decode path
                # processes). After a preemption this also re-covers the
                # already-generated tokens, whose KV was dropped.
                remaining = len(seq.tokens) - 1 - seq.n_cached
                if remaining <= 0:
                    # single-token prompt or fully prefix-cached: prefill is
                    # vacuously done; emit an empty chunk so the engine runs
                    # the done-transition (slot alloc / migration).
                    plan.prefill.append((seq, seq.n_cached, []))
                    if seq not in self.prefilling:
                        self.prefilling.append(seq)
                    continue
                if budget <= 0:
                    if seq not in self.prefilling:
                        self.ready.appendleft(seq)
                    continue
                take = min(self.cfg.chunk_size, budget, remaining)
                chunk = seq.tokens[seq.n_cached: seq.n_cached + take]
                plan.prefill.append((seq, seq.n_cached, chunk))
                if seq not in self.prefilling:
                    self.prefilling.append(seq)
                budget -= take
        self.sched_time += time.monotonic() - t0
        return plan

    def safe_horizon(self, batch: List[SequenceState], k_target: int,
                     budget: int) -> int:
        """Multi-step decode proof (DESIGN.md §8): K decode+sample steps may
        run as ONE fused device dispatch iff the scheduler can show that for
        the next K steps (a) no prefill admission can interleave — every
        queue except ``running`` is empty, (b) the batch IS the whole
        running set (composition cannot change under it), and (c) no member
        can exhaust its ``max_new_tokens`` budget mid-horizon. EOS cannot be
        proven ahead of sampling, so the engine checks it one horizon late
        and discards post-stop tokens. Scheduling the horizon needs only
        token COUNTS, never values — the same §4.2 property that makes
        async single-step planning sound."""
        if k_target <= 1 or budget <= 1:
            return 1
        if self.waiting or self.prefetching or self.ready or self.prefilling:
            return 1
        if len(batch) != len(self.running):
            return 1
        return min(k_target, budget)

    # ------------------------------------------------------------ metrics
    def queued_seqs(self) -> List[SequenceState]:
        """Every sequence admitted but not yet fully prefilled."""
        return (list(self.waiting) + list(self.ready)
                + [s for s, _ in self.prefetching] + list(self.prefilling))

    def queued_prefill_tokens(self) -> int:
        """Prefill tokens still owed to queued sequences — the prefill half
        of the JE's live load signal (DESIGN.md §9)."""
        return sum(max(0, len(s.tokens) - 1 - s.n_cached)
                   for s in self.queued_seqs())

    def queue_depth(self) -> int:
        return (len(self.waiting) + len(self.ready) + len(self.prefetching)
                + len(self.prefilling))

    def occupancy(self) -> float:
        """Fraction of the decode batch in use (0 ⇒ idle, ≥1 ⇒ saturated —
        running may exceed the per-step batch; plans slice it)."""
        return len(self.running) / max(1, self.cfg.max_decode_batch)

    # ------------------------------------------------------------ commits
    def admit_running(self, seq: SequenceState) -> None:
        """Decode-TE admission of a migrated-in sequence (the PD-pair
        steady path, DESIGN.md §9): the sequence arrives fully prefilled —
        its KV may still be in flight (``_kv_pending``) — and joins the
        decode set directly, bypassing the prefill queues."""
        self.running.append(seq)

    def on_prefill_progress(self, seq: SequenceState, done: bool) -> None:
        if done:
            if seq in self.prefilling:
                self.prefilling.remove(seq)
            if self.cfg.mode == "prefill":
                return  # engine hands the seq to the decode TE (PD-disagg)
            self.running.append(seq)

    def on_finished(self, seq: SequenceState) -> None:
        if seq in self.running:
            self.running.remove(seq)

    def preempt_victim(self) -> Optional[SequenceState]:
        """Pick the most recently admitted running seq to preempt."""
        return self.running[-1] if self.running else None

    def remove(self, seq: SequenceState) -> None:
        """Forget a sequence that left this engine WITHOUT finishing here —
        a mid-decode migration to another TE (drain, DESIGN.md §9). A
        zombie left in ``running`` would keep ``has_work`` true forever,
        which blocks a draining TE's release."""
        if seq in self.running:
            self.running.remove(seq)
        if seq in self.prefilling:
            self.prefilling.remove(seq)
        try:
            self.ready.remove(seq)
        except ValueError:
            pass
        try:
            self.waiting.remove(seq)
        except ValueError:
            pass
        self.prefetching = [(s, t) for s, t in self.prefetching
                            if s is not seq]

    def requeue(self, seq: SequenceState) -> None:
        if seq in self.running:
            self.running.remove(seq)
        if seq in self.prefilling:
            self.prefilling.remove(seq)
        seq.n_cached = 0
        seq.pages = []
        self.waiting.appendleft(seq)

    def has_work(self) -> bool:
        return bool(self.waiting or self.prefetching or self.ready
                    or self.prefilling or self.running)
