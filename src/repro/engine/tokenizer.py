"""Self-contained byte-level tokenizer (FLOWSERVE's tokenizer module).

The paper treats the tokenizer as an independent, separately-scalable
module; ours is a deterministic byte-level codec with special tokens so
prefix-cache keys are stable across processes. Token ids: 0=PAD, 1=BOS,
2=EOS, 3..258 = bytes. Always fits every assigned vocab (min 32000).
"""
from __future__ import annotations

from typing import List

PAD_ID, BOS_ID, EOS_ID = 0, 1, 2
_BYTE_OFFSET = 3
VOCAB_FLOOR = 259


class ByteTokenizer:
    def __init__(self, vocab_size: int = VOCAB_FLOOR):
        assert vocab_size >= VOCAB_FLOOR, vocab_size
        self.vocab_size = vocab_size

    def encode(self, text: str, bos: bool = True) -> List[int]:
        ids = [b + _BYTE_OFFSET for b in text.encode("utf-8")]
        return ([BOS_ID] + ids) if bos else ids

    def decode(self, ids) -> str:
        bs = bytes(i - _BYTE_OFFSET for i in ids
                   if _BYTE_OFFSET <= i < _BYTE_OFFSET + 256)
        return bs.decode("utf-8", errors="replace")
