"""FLOWSERVE model-generator backends (the per-NPU executor side).

Two runners cover the model zoo:

  * ``PagedRunner`` — attention-only towers (dense / MoE / SWA /
    local-global / qk-norm): true paged-KV continuous batching. Decode is
    one jit'd step over the whole page pool (donated); prefill runs in
    chunks that scatter fresh KV into pages (chunked prefill, §4.2).
    On TPU the attention inside these steps dispatches to the Pallas
    paged_attention / flash_prefill kernels via repro.kernels.ops.

  * ``SlotRunner`` — recurrent / hybrid / cross-attention families (rwkv6,
    recurrentgemma, seamless enc-dec, llama-vision): fixed batch slots with
    dense per-slot caches (their state is O(1) or includes modality
    memories). Continuous batching assigns sequences to free slots; prefix
    reuse is state-checkpoint based (DESIGN.md §4).

Both expose: prefill_chunk(seq, tokens) -> Optional[logits_row],
decode(seqs) -> logits (B, Vp), plus export/import hooks for PD
disaggregation (DistFlow payloads).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.engine.kv_cache import PagedKVPool, pages_needed
from repro.kernels import ref as KREF
from repro.launch import sharding as SH
from repro.models import layers as L
from repro.models import serving as S
from repro.models import transformer as T
from repro.models.model_factory import ModelBundle


def pick_runner(cfg: ModelConfig) -> str:
    if cfg.attn_kind in ("global", "swa", "local_global") and cfg.vision is None \
            and cfg.encoder is None:
        return "paged"
    return "slot"


@dataclass
class SequenceState:
    seq_id: str
    tokens: List[int]                   # full token ids (prompt + generated)
    n_prompt: int
    n_cached: int = 0                   # tokens with KV/state materialized
    pages: List[int] = field(default_factory=list)
    reused_pages: int = 0               # prefix-cache pages (shared, pinned)
    slot: Optional[int] = None          # SlotRunner slot id
    state: Any = None                   # SlotRunner per-seq state snapshot
    extra: Dict[str, Any] = field(default_factory=dict)  # modality stubs


# ===========================================================================
# Paged runner
# ===========================================================================


class PagedRunner:
    """With ``mesh`` set (EngineConfig.tp > 1) the runner is the TE's SPMD
    executor: weights live sharded per launch/sharding.py's policy, the page
    pool shards whole KV heads over `model`, and the jit'd decode/prefill
    steps pin in_shardings/out_shardings so every step is one SPMD program
    spanning the mesh (collectives inserted by GSPMD)."""

    def __init__(self, bundle: ModelBundle, params, pool: PagedKVPool,
                 dtype=jnp.float32, mesh=None):
        self.bundle = bundle
        self.cfg = bundle.cfg
        self.pool = pool
        self.dtype = dtype
        self.mesh = mesh
        if mesh is not None:
            self._param_sh = SH.engine_param_shardings(self.cfg, params, mesh)
            self._kv_sh = pool.sharding if pool.sharding is not None \
                else SH.engine_kv_pool_sharding(self.cfg, mesh)
            self._repl = NamedSharding(mesh, P())
            params = jax.device_put(params, self._param_sh)
        self.params = params
        self._wins = [int(w) for w in np.asarray(T.window_schedule(self.cfg))]
        self._decode_fns: Dict[int, Any] = {}
        self._prefill_fns: Dict[Tuple[int, int], Any] = {}
        # decode hot loop (DESIGN.md §8): bucketed fused decode+sample jits,
        # keyed (k_steps, batch_bucket, page_bucket); jit_compiles counts
        # decode-path cache misses so the engine can assert zero recompiles
        # in steady state after the warmup pass.
        self._fused_fns: Dict[Tuple[int, int, int], Any] = {}
        self.jit_compiles = 0

    def _jit_step(self, fn, donate: Tuple[int, ...]):
        """jit with TP shardings pinned when the runner spans a mesh:
        weights keep their placement, token/page operands replicate, and the
        (donated) KV pool stays head-sharded in and out."""
        if self.mesh is None:
            return jax.jit(fn, donate_argnums=donate)
        r, kv = self._repl, self._kv_sh
        return jax.jit(fn, donate_argnums=donate,
                       in_shardings=(self._param_sh, r, r, r, kv, kv),
                       out_shardings=(r, kv, kv))

    # ------------------------------------------------------------ decode
    def decode(self, seqs: List[SequenceState]) -> jax.Array:
        """One decode step for a batch of sequences. The new token of each
        seq is seqs[i].tokens[-1]; KV is written at position len(tokens)-1.
        Caller must have appended a page if needed."""
        b = len(seqs)
        maxp = max(len(s.pages) for s in seqs)
        bt = np.zeros((b, maxp), np.int32)
        for i, s in enumerate(seqs):
            bt[i, :len(s.pages)] = s.pages
        tokens = jnp.asarray([s.tokens[-1] for s in seqs], jnp.int32)
        lengths = jnp.asarray([len(s.tokens) for s in seqs], jnp.int32)
        fn = self._decode_fn(maxp)
        logits, self.pool.k, self.pool.v = fn(
            self.params, tokens, jnp.asarray(bt), lengths, self.pool.k, self.pool.v)
        for s in seqs:
            s.n_cached = len(s.tokens)
        return logits

    def _decode_body(self, params, tokens, bt, lengths, k_pool, v_pool):
        """Traceable single decode step: (B,) token ids + device metadata →
        (B, Vp) logits + updated pools. Shared by the legacy per-step jit and
        the fused decode+sample horizon (DESIGN.md §8)."""
        cfg = self.cfg
        wins = self._wins
        ps = self.pool.page_size
        b = tokens.shape[0]
        x = T.embed(cfg, params, tokens[:, None])
        pos = (lengths - 1)[:, None]
        bidx = jnp.arange(b)
        page = bt[bidx, (lengths - 1) // ps]
        slot = (lengths - 1) % ps
        for li in range(cfg.n_layers):
            p = jax.tree.map(lambda a: a[li], params["blocks"])
            h = L.apply_norm(x, p["ln1"], cfg.norm)
            q, k_new, v_new = L.attn_qkv(p["attn"], h, cfg.n_heads,
                                         cfg.n_kv_heads, cfg.head_dim,
                                         pos, cfg.rope_theta, cfg.qk_norm)
            k_pool = k_pool.at[li, page, slot].set(k_new[:, 0])
            v_pool = v_pool.at[li, page, slot].set(v_new[:, 0])
            win = wins[li] if wins[li] < T.GLOBAL_WINDOW else None
            o = KREF.paged_attention_ref(q[:, 0], k_pool[li], v_pool[li],
                                         bt, lengths,
                                         softcap=cfg.attn_logit_softcap,
                                         window=win)
            x = x + S._post_attn(cfg, p, L.attn_out(p["attn"], o[:, None]))
            h = L.apply_norm(x, p["ln2"], cfg.norm)
            if "moe" in p:
                from repro.models import moe as M
                m = M.moe_apply(p["moe"], h, cfg.moe, cfg.mlp_act, groups=1)
            else:
                m = L.mlp_apply(p["mlp"], h, cfg.mlp_act)
            if cfg.post_norms:
                m = L.apply_norm(m, p["ln2_post"], cfg.norm)
            x = x + m
        logits = T.unembed(cfg, params, x)[:, 0]
        return logits, k_pool, v_pool

    def _decode_fn(self, maxp: int):
        if maxp in self._decode_fns:
            return self._decode_fns[maxp]
        self.jit_compiles += 1

        def step(params, tokens, bt, lengths, k_pool, v_pool):
            return self._decode_body(params, tokens, bt, lengths,
                                     k_pool, v_pool)

        step = self._jit_step(step, donate=(4, 5))
        self._decode_fns[maxp] = step
        return step

    # ---------------------------------------------- fused decode hot loop
    def decode_fused(self, state, k_steps: int) -> jax.Array:
        """NPU-centric decode (DESIGN.md §8): run ``k_steps`` decode+sample
        iterations as ONE device dispatch over the persistent device-resident
        batch state. Sampling is fused into the step — logits never leave the
        device — and the carried metadata (lengths, last tokens, PRNG key)
        advances in-jit, so the host's only job is this dispatch. Returns the
        (k_steps, batch_bucket) sampled-token block WITHOUT materializing it
        on the host; the caller fetches it asynchronously a horizon later."""
        fn = self._decode_fused_fn(k_steps, state.bb, state.pb)
        (toks, state.key, state.last_tok, state.lengths,
         self.pool.k, self.pool.v) = fn(
            self.params, state.bt, state.active, state.temps, state.top_ps,
            state.key, state.last_tok, state.lengths,
            self.pool.k, self.pool.v)
        return toks

    def _decode_fused_fn(self, k_steps: int, bb: int, pb: int):
        key_t = (k_steps, bb, pb)
        fn = self._fused_fns.get(key_t)
        if fn is not None:
            return fn
        self.jit_compiles += 1
        cfg = self.cfg
        from repro.engine.sampling import greedy_core, sample_core

        def horizon(params, bt, active, temps, top_ps, key, last_tok,
                    lengths, k_pool, v_pool):
            act = active.astype(jnp.int32)
            # the all-greedy shortcut v1's sample_batch takes on the host,
            # moved in-jit: one traced predicate selects pure argmax over the
            # full top-p pipeline at runtime (per-row results are identical)
            all_greedy = jnp.all(temps <= 0.0)

            def one(carry, _):
                key, last_tok, lengths, k_pool, v_pool = carry
                logits, k_pool, v_pool = self._decode_body(
                    params, last_tok, bt, lengths, k_pool, v_pool)
                key, sub = jax.random.split(key)
                toks = jax.lax.cond(
                    all_greedy,
                    lambda lg: greedy_core(lg, cfg.vocab_size),
                    lambda lg: sample_core(lg, temps, top_ps, sub,
                                           cfg.vocab_size),
                    logits)
                # padding rows: freeze token + length so their KV write stays
                # parked at slot 0 of the pool's scratch page forever
                toks = jnp.where(active, toks, last_tok)
                return (key, toks, lengths + act, k_pool, v_pool), toks

            (key, last_tok, lengths, k_pool, v_pool), toks = jax.lax.scan(
                one, (key, last_tok, lengths, k_pool, v_pool), None,
                length=k_steps)
            return toks, key, last_tok, lengths, k_pool, v_pool

        if self.mesh is None:
            fn = jax.jit(horizon, donate_argnums=(8, 9))
        else:
            r, kv = self._repl, self._kv_sh
            fn = jax.jit(horizon, donate_argnums=(8, 9),
                         in_shardings=(self._param_sh, r, r, r, r, r, r, r,
                                       kv, kv),
                         out_shardings=(r, r, r, r, kv, kv))
        self._fused_fns[key_t] = fn
        return fn

    def warmup_fused(self, batch_buckets, page_buckets, horizons) -> int:
        """Precompile the bucketed fused decode jits ahead of serving (the
        §4.2 warmup pass) so steady state never recompiles. Runs each bucket
        combination once against a transient throwaway KV pool (donated and
        chained call-to-call, so the warmup never touches live pages and
        peaks at one extra pool copy). Returns the number of executables
        compiled. Note: ``jit.lower().compile()`` does NOT seed the dispatch
        cache on this jax version, so the warmup must really call."""
        k = jnp.zeros_like(self.pool.k)
        v = jnp.zeros_like(self.pool.v)
        if self.mesh is not None:
            k = jax.device_put(k, self._kv_sh)
            v = jax.device_put(v, self._kv_sh)
        key = jax.random.PRNGKey(0)
        n = 0
        for k_steps in sorted(set(horizons)):
            for bb in sorted(set(batch_buckets)):
                for pb in sorted(set(page_buckets)):
                    fn = self._decode_fused_fn(k_steps, bb, pb)
                    _, key, _, _, k, v = fn(
                        self.params, jnp.zeros((bb, pb), jnp.int32),
                        jnp.zeros((bb,), bool), jnp.zeros((bb,), jnp.float32),
                        jnp.ones((bb,), jnp.float32), key,
                        jnp.zeros((bb,), jnp.int32),
                        jnp.ones((bb,), jnp.int32), k, v)
                    n += 1
        jax.block_until_ready(k)
        return n

    # ------------------------------------------------------------ prefill
    def prefill_chunk(self, seq: SequenceState, chunk_tokens: List[int]
                      ) -> Optional[jax.Array]:
        """Run one prompt chunk; returns last-token logits when this chunk
        completes the prompt (so the engine can sample the first token)."""
        c = len(chunk_tokens)
        start = seq.n_cached
        npages = len(seq.pages)
        fn = self._prefill_fn(c, npages)
        tokens = jnp.asarray(chunk_tokens, jnp.int32)[None]
        bt = jnp.asarray(seq.pages, jnp.int32)[None]
        logits, self.pool.k, self.pool.v = fn(
            self.params, tokens, jnp.asarray([start], jnp.int32), bt,
            self.pool.k, self.pool.v)
        seq.n_cached = start + c
        if seq.n_cached >= seq.n_prompt:
            return logits[0]
        return None

    def _prefill_fn(self, c: int, npages: int):
        key = (c, npages)
        if key in self._prefill_fns:
            return self._prefill_fns[key]
        cfg = self.cfg
        wins = self._wins
        ps = self.pool.page_size

        def run(params, tokens, start, bt, k_pool, v_pool):
            x = T.embed(cfg, params, tokens)                    # (1,C,D)
            positions = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None]
            flat = start[0] + jnp.arange(c)
            page = bt[0, flat // ps]
            slot = flat % ps
            total = npages * ps
            kpos_base = jnp.arange(total, dtype=jnp.int32)[None]
            for li in range(cfg.n_layers):
                p = jax.tree.map(lambda a: a[li], params["blocks"])
                h = L.apply_norm(x, p["ln1"], cfg.norm)
                q, k_new, v_new = L.attn_qkv(p["attn"], h, cfg.n_heads,
                                             cfg.n_kv_heads, cfg.head_dim,
                                             positions, cfg.rope_theta, cfg.qk_norm)
                k_pool = k_pool.at[li, page, slot].set(k_new[0])
                v_pool = v_pool.at[li, page, slot].set(v_new[0])
                k_seq = k_pool[li, bt[0]].reshape(1, total, cfg.n_kv_heads, cfg.head_dim)
                v_seq = v_pool[li, bt[0]].reshape(1, total, cfg.n_kv_heads, cfg.head_dim)
                kpos = jnp.where(kpos_base < (start[0] + c), kpos_base,
                                 T.GLOBAL_WINDOW + 1)
                mask = L.causal_mask(positions, kpos)
                mask &= kpos[:, None, :] > (positions[:, :, None] - wins[li])
                o = L.attention(q, k_seq, v_seq, mask, cfg.attn_logit_softcap)
                x = x + S._post_attn(cfg, p, L.attn_out(p["attn"], o))
                h = L.apply_norm(x, p["ln2"], cfg.norm)
                if "moe" in p:
                    from repro.models import moe as M
                    m = M.moe_apply(p["moe"], h, cfg.moe, cfg.mlp_act, groups=1)
                else:
                    m = L.mlp_apply(p["mlp"], h, cfg.mlp_act)
                if cfg.post_norms:
                    m = L.apply_norm(m, p["ln2_post"], cfg.norm)
                x = x + m
            logits = T.unembed(cfg, params, x[:, -1:])[:, 0]
            return logits, k_pool, v_pool

        run = self._jit_step(run, donate=(4, 5))
        self._prefill_fns[key] = run
        return run

    # ------------------------------------------------------------ PD export
    def export_kv(self, seq: SequenceState, host_gather: bool = False):
        """DistFlow payload for PD-disaggregation: page run + metadata.

        Default (v2): the run stays a sharded ``jax.Array`` pair — one jit'd
        gather, no host round-trip; DistFlow moves/reshards it device-to-
        device. ``host_gather=True`` keeps the v1 numpy path (benchmark
        baseline and DCN/pickle-style escape hatch)."""
        meta = {"tokens": list(seq.tokens), "n_prompt": seq.n_prompt,
                "n_cached": seq.n_cached, "n_pages": len(seq.pages)}
        if host_gather:
            k, v = self.pool.gather(seq.pages)
            return {"k": np.asarray(k), "v": np.asarray(v),
                    "host_gather": True, **meta}
        k, v = self.pool.gather_device(seq.pages)
        return {"k": k, "v": v, **meta}

    def import_kv(self, payload, pages: List[int]) -> None:
        """Install a migrated page run. v2 payloads (device arrays or the
        layer-chunked ``{"chunks": [...]}`` a MigrationHandle.wait() yields)
        go through the donated jit'd scatter; v1 host payloads keep the
        un-jitted full-pool rewrite for benchmark comparison."""
        if payload.get("host_gather"):
            idx = jnp.asarray(pages[:payload["k"].shape[1]], jnp.int32)
            self.pool.k = self.pool.k.at[:, idx].set(jnp.asarray(payload["k"]))
            self.pool.v = self.pool.v.at[:, idx].set(jnp.asarray(payload["v"]))
            self.pool.full_pool_copies += 2          # k and v each rewritten
            return
        chunks = payload.get("chunks")
        if chunks is None:
            chunks = [(0, payload["k"], payload["v"])]
        # the run covers the pages allocated at import time; a lazy (overlap)
        # import may fire after _ensure_pages appended the next decode page
        pages = pages[:chunks[0][1].shape[1]]
        target = self.pool.run_sharding()
        for l0, k_run, v_run in chunks:
            # no-op when DistFlow already resharded onto this mesh; real
            # placement change only for payloads that skipped transfer_sharded
            k_run = jax.device_put(k_run, target)
            v_run = jax.device_put(v_run, target)
            self.pool.scatter_run(pages, k_run, v_run, layer_start=l0)


# ===========================================================================
# Slot runner (recurrent / hybrid / cross-attention families)
# ===========================================================================


class SlotRunner:
    def __init__(self, bundle: ModelBundle, params, n_slots: int, max_len: int,
                 dtype=jnp.float32, mesh=None):
        self.bundle = bundle
        self.cfg = bundle.cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.dtype = dtype
        self.mesh = mesh
        cache = bundle.init_cache(n_slots, max_len, dtype)
        if mesh is not None:
            # SPMD TE: weights + dense per-slot caches shard per
            # launch/sharding.py (k/v shard the sequence dim over the mesh;
            # recurrent state shards its width/head dims where divisible).
            self._param_sh = SH.engine_param_shardings(self.cfg, params, mesh)
            self._cache_sh = SH.engine_cache_shardings(self.cfg, cache, mesh,
                                                       n_slots, max_len)
            self._repl = NamedSharding(mesh, P())
            params = jax.device_put(params, self._param_sh)
            cache = jax.device_put(cache, self._cache_sh)
            self._decode_jit = jax.jit(
                lambda p, t, c: S.decode_step(self.cfg, p, t, c),
                in_shardings=(self._param_sh, self._repl, self._cache_sh),
                out_shardings=(self._repl, self._cache_sh))
        else:
            self._decode_jit = jax.jit(
                lambda p, t, c: S.decode_step(self.cfg, p, t, c))
        self.params = params
        self.cache = cache
        self.free_slots = list(range(n_slots))
        self._prefill_jits: Dict[int, Any] = {}

    # batch-dim axis for every cache leaf except `length`
    def _slot_slice(self, slot: int):
        def f(path, a):
            if path == "length":
                return a[slot:slot + 1]
            return a[:, slot:slot + 1]
        return {k: f(k, v) for k, v in self.cache.items()}

    def _slot_write(self, slot: int, sub):
        for k, v in sub.items():
            if k == "length":
                self.cache[k] = self.cache[k].at[slot].set(v[0])
            else:
                self.cache[k] = self.cache[k].at[:, slot].set(v[:, 0])

    def alloc_slot(self, seq: SequenceState) -> bool:
        if not self.free_slots:
            return False
        seq.slot = self.free_slots.pop()
        # reset slot length AND recurrent/conv state — stale KV is masked by
        # length, but recurrent state would leak the previous occupant.
        self.cache["length"] = self.cache["length"].at[seq.slot].set(0)
        for key in ("state", "last_tm", "last_cm", "h", "conv"):
            if key in self.cache:
                self.cache[key] = self.cache[key].at[:, seq.slot].set(0)
        return True

    def free_slot(self, seq: SequenceState) -> None:
        if seq.slot is not None:
            self.free_slots.append(seq.slot)
            seq.slot = None

    def prefill_chunk(self, seq: SequenceState, chunk_tokens: List[int]
                      ) -> Optional[jax.Array]:
        c = len(chunk_tokens)
        sub = self._slot_slice(seq.slot)
        fn = self._prefill_fn(c)
        extra = {k: jnp.asarray(v) for k, v in seq.extra.items()}
        logits, sub = fn(self.params, jnp.asarray(chunk_tokens, jnp.int32)[None],
                         sub, extra)
        self._slot_write(seq.slot, sub)
        seq.n_cached += c
        if seq.n_cached >= seq.n_prompt:
            return logits[0]
        return None

    def _prefill_fn(self, c: int):
        if c in self._prefill_jits:
            return self._prefill_jits[c]
        cfg = self.cfg

        def run(params, tokens, cache, extra):
            return S.prefill(cfg, params, tokens, cache, **extra)

        if self.mesh is not None:
            # `extra` (modality stubs) replicates: a single sharding works as
            # a pytree prefix over the whole dict.
            run = jax.jit(run, in_shardings=(self._param_sh, self._repl,
                                             self._cache_sh, self._repl),
                          out_shardings=(self._repl, self._cache_sh))
        else:
            run = jax.jit(run)
        self._prefill_jits[c] = run
        return self._prefill_jits[c]

    def decode(self, seqs: List[SequenceState]) -> jax.Array:
        """Decode all active slots in one batched step; returns logits rows
        aligned with `seqs` order."""
        tokens = np.zeros((self.n_slots,), np.int32)
        for s in seqs:
            tokens[s.slot] = s.tokens[-1]
        logits, self.cache = self._decode_jit(self.params,
                                              jnp.asarray(tokens), self.cache)
        for s in seqs:
            s.n_cached = len(s.tokens)
        return logits[jnp.asarray([s.slot for s in seqs])]

    # state checkpointing (prefix cache for recurrent archs)
    def snapshot_state(self, seq: SequenceState):
        sub = self._slot_slice(seq.slot)
        return jax.tree.map(np.asarray, sub)

    def restore_state(self, seq: SequenceState, snap) -> None:
        self._slot_write(seq.slot, jax.tree.map(jnp.asarray, snap))
        seq.n_cached = int(snap["length"][0])

    def export_kv(self, seq: SequenceState):
        return {"state": self.snapshot_state(seq), "tokens": list(seq.tokens),
                "n_prompt": seq.n_prompt, "n_cached": seq.n_cached}

    def import_kv(self, payload, seq: SequenceState) -> None:
        self.restore_state(seq, payload["state"])
