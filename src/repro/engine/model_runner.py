"""Compatibility shim — the runners moved to ``repro.engine.runners``
(DESIGN.md §12: per-family Prefill/Decode microkernel pairs behind a
registry). This module re-exports the public names so existing imports
(`scheduler`, tests, downstream scripts) keep working; new code should
import from ``repro.engine.runners``.
"""
from repro.engine.runners import (PagedRunner, SequenceState,  # noqa: F401
                                  SlotRunner, pick_runner)

__all__ = ["PagedRunner", "SequenceState", "SlotRunner", "pick_runner"]
