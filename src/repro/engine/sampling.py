"""Sampling for FLOWSERVE's model generator: greedy / temperature / top-p."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0      # 0 => greedy
    top_p: float = 1.0
    max_new_tokens: int = 64
    stop_on_eos: bool = True


def sample(logits: jax.Array, params: SamplingParams, key: jax.Array,
           vocab_size: int) -> jax.Array:
    """logits: (B, Vp) -> token ids (B,). Pad-vocab ids are masked."""
    vp = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if vp > vocab_size:
        logits = jnp.where(jnp.arange(vp)[None, :] >= vocab_size, -1e30, logits)
    if params.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / params.temperature
    if params.top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < params.top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
        logits = jnp.where(logits < cutoff, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
