"""Sampling for FLOWSERVE's model generator: greedy / temperature / top-p.

Three entry points:
  * ``sample``       — one SamplingParams for a whole logits batch (oracle /
                       offline paths).
  * ``sample_batch`` — per-row temperature/top-p as arrays, one jit'd device
                       dispatch for the whole decode batch (one
                       ``fold_in``-free split per step, not one dispatch per
                       sequence).
  * ``sample_core``  — the traceable per-row sampling math itself, shared by
                       ``sample_batch`` and the fused decode+sample step
                       (DESIGN.md §8): fusing callers inline it into the
                       decode jit so logits never leave the device, and both
                       paths stay bit-identical by construction.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0      # 0 => greedy
    top_p: float = 1.0
    max_new_tokens: int = 64
    stop_on_eos: bool = True


def sample(logits: jax.Array, params: SamplingParams, key: jax.Array,
           vocab_size: int) -> jax.Array:
    """logits: (B, Vp) -> token ids (B,). Pad-vocab ids are masked."""
    vp = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if vp > vocab_size:
        logits = jnp.where(jnp.arange(vp)[None, :] >= vocab_size, -1e30, logits)
    if params.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / params.temperature
    if params.top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < params.top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
        logits = jnp.where(logits < cutoff, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sample_core(logits: jax.Array, temperature: jax.Array, top_p: jax.Array,
                key: jax.Array, vocab_size: int) -> jax.Array:
    """Traceable per-row sampling: (B, Vp) logits + per-row params + ONE step
    key -> (B,) token ids. Every row is independent, so a bucket-padded batch
    samples its real rows bit-identically to the exact-size batch (greedy
    rows never consume randomness)."""
    vp = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if vp > vocab_size:
        logits = jnp.where(jnp.arange(vp)[None, :] >= vocab_size, -1e30, logits)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    # greedy rows (t<=0) still flow through the stochastic path below with a
    # clamped temperature; their result is discarded by the final where.
    t = jnp.maximum(temperature, 1e-6)[:, None]
    scaled = logits / t
    sorted_logits = jnp.sort(scaled, axis=-1)[:, ::-1]
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    cutoff_idx = jnp.sum(cum < top_p[:, None], axis=-1, keepdims=True)
    cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
    limited = jnp.where(scaled < cutoff, -1e30, scaled)
    final = jnp.where((top_p < 1.0)[:, None], limited, scaled)
    keys = jax.random.split(key, logits.shape[0])
    drawn = jax.vmap(
        lambda k, row: jax.random.categorical(k, row))(keys, final)
    return jnp.where(temperature <= 0.0, greedy, drawn.astype(jnp.int32))


@functools.partial(jax.jit, static_argnums=(4,))
def _sample_batch(logits: jax.Array, temperature: jax.Array, top_p: jax.Array,
                  key: jax.Array, vocab_size: int) -> jax.Array:
    return sample_core(logits, temperature, top_p, key, vocab_size)


def greedy_core(logits: jax.Array, vocab_size: int) -> jax.Array:
    """Traceable pad-masked argmax — the all-greedy shortcut. Row-for-row
    identical to ``sample_core`` at temperature<=0, without the
    sort/softmax/cumsum pipeline (fused decode branches here via
    ``lax.cond`` when every row of the batch is greedy)."""
    vp = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if vp > vocab_size:
        logits = jnp.where(jnp.arange(vp)[None, :] >= vocab_size, -1e30, logits)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnums=(1,))
def _greedy_batch(logits: jax.Array, vocab_size: int) -> jax.Array:
    return greedy_core(logits, vocab_size)


def sample_batch(logits: jax.Array, temperature, top_p, key: jax.Array,
                 vocab_size: int) -> jax.Array:
    """logits: (B, Vp) with per-row params -> token ids (B,). One device
    dispatch for the whole batch; an all-greedy batch (the common serving
    default) skips the sort/softmax/categorical pipeline entirely."""
    temperature = np.asarray(temperature, np.float32)
    if temperature.size == 0 or float(temperature.max()) <= 0.0:
        return _greedy_batch(logits, vocab_size)
    return _sample_batch(logits, jnp.asarray(temperature),
                         jnp.asarray(top_p, jnp.float32), key, vocab_size)
