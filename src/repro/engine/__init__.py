from repro.engine.flowserve import FlowServe, EngineConfig, Request, Completion  # noqa: F401
from repro.engine.hotloop import DecodeHotState  # noqa: F401
from repro.engine.sampling import SamplingParams  # noqa: F401
from repro.engine.tokenizer import ByteTokenizer  # noqa: F401
