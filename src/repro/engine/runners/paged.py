"""Paged-KV runner family: attention-only towers (dense / MoE / SWA /
local-global / qk-norm) batching through the engine's paged pool.

``PagedRunner`` is the family facade — it owns the shared executor state
(params + shardings, page pool, per-layer window schedule, compile
counters) and delegates to the two phase microkernels (DESIGN.md §12):

  * ``PagedPrefillRunner`` — chunked prefill. Two shapes of the same
    scatter-then-attend step:
      - ``prefill_ragged``: the WHOLE step's prefill plan — every
        sequence's chunk, ragged lengths and all — packed into ONE padded
        pow2-bucketed dispatch. Flat token stream with per-token
        (page, slot, position) indices, one KV scatter per layer across
        all sequences, per-token block-table rows for the gather, logits
        taken only at chunk-final rows, and first-token sampling fused in
        (``sample_core`` under a ``lax.cond`` all-greedy shortcut) so a
        completing prompt leaves the dispatch with its first token.
      - ``prefill_chunk``: the legacy batch-1 per-sequence path, kept
        behind ``EngineConfig.batched_prefill=False`` for parity testing.
  * ``PagedDecodeRunner`` — the decode hot loop (DESIGN.md §8): legacy
    per-step jit plus the fused decode+sample K-step horizon.

With ``mesh`` set (EngineConfig.tp > 1) the facade is the TE's SPMD
executor: weights live sharded per launch/sharding.py's policy, the page
pool shards whole KV heads over `model`, and every phase jit pins
in/out shardings so each step is one SPMD program spanning the mesh.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.engine.kv_cache import PagedKVPool
from repro.engine.runners.base import SequenceState
from repro.kernels import ref as KREF
from repro.launch import sharding as SH
from repro.models import layers as L
from repro.models import serving as S
from repro.models import transformer as T
from repro.models.model_factory import ModelBundle


class PagedRunner:
    """Family facade: shared state + phase delegation (public API of the
    pre-registry PagedRunner, preserved verbatim)."""

    def __init__(self, bundle: ModelBundle, params, pool: PagedKVPool,
                 dtype=jnp.float32, mesh=None):
        self.bundle = bundle
        self.cfg = bundle.cfg
        self.pool = pool
        self.dtype = dtype
        self.mesh = mesh
        if mesh is not None:
            self._param_sh = SH.engine_param_shardings(self.cfg, params, mesh)
            self._kv_sh = pool.sharding if pool.sharding is not None \
                else SH.engine_kv_pool_sharding(self.cfg, mesh)
            self._repl = NamedSharding(mesh, P())
            params = jax.device_put(params, self._param_sh)
        self.params = params
        self._wins = [int(w) for w in np.asarray(T.window_schedule(self.cfg))]
        # jit_compiles counts DECODE-path cache misses (bucketed keys ⇒ 0 in
        # steady state after warmup); prefill_jit_compiles is the prefill
        # side of the same accounting — split counters because the engine's
        # warmup passes are per-phase.
        self.jit_compiles = 0
        self.prefill_jit_compiles = 0
        self.prefill = PagedPrefillRunner(self)
        self.decoder = PagedDecodeRunner(self)

    def _jit_step(self, fn, donate: Tuple[int, ...]):
        """jit with TP shardings pinned when the runner spans a mesh:
        weights keep their placement, token/page operands replicate, and the
        (donated) KV pool stays head-sharded in and out."""
        if self.mesh is None:
            return jax.jit(fn, donate_argnums=donate)
        r, kv = self._repl, self._kv_sh
        return jax.jit(fn, donate_argnums=donate,
                       in_shardings=(self._param_sh, r, r, r, kv, kv),
                       out_shardings=(r, kv, kv))

    # phase delegation — the facade keeps the flat call surface the engine
    # and tests use; each method body lives on exactly one phase runner.
    def decode(self, seqs: List[SequenceState]) -> jax.Array:
        return self.decoder.decode(seqs)

    def decode_fused(self, state, k_steps: int) -> jax.Array:
        return self.decoder.decode_fused(state, k_steps)

    def warmup_fused(self, batch_buckets, page_buckets, horizons) -> int:
        return self.decoder.warmup_fused(batch_buckets, page_buckets,
                                         horizons)

    def prefill_chunk(self, seq: SequenceState, chunk_tokens: List[int]
                      ) -> Optional[jax.Array]:
        return self.prefill.prefill_chunk(seq, chunk_tokens)

    def prefill_ragged(self, *args, **kw):
        return self.prefill.prefill_ragged(*args, **kw)

    def warmup_ragged(self, token_buckets, page_buckets, n_rows: int) -> int:
        return self.prefill.warmup_ragged(token_buckets, page_buckets,
                                          n_rows)

    # ------------------------------------------------------------ PD export
    def export_kv(self, seq: SequenceState, host_gather: bool = False):
        """DistFlow payload for PD-disaggregation: page run + metadata.

        Default (v2): the run stays a sharded ``jax.Array`` pair — one jit'd
        gather, no host round-trip; DistFlow moves/reshards it device-to-
        device. ``host_gather=True`` keeps the v1 numpy path (benchmark
        baseline and DCN/pickle-style escape hatch)."""
        meta = {"tokens": list(seq.tokens), "n_prompt": seq.n_prompt,
                "n_cached": seq.n_cached, "n_pages": len(seq.pages)}
        if host_gather:
            k, v = self.pool.gather(seq.pages)
            return {"k": np.asarray(k), "v": np.asarray(v),
                    "host_gather": True, **meta}
        k, v = self.pool.gather_device(seq.pages)
        return {"k": k, "v": v, **meta}

    def import_kv(self, payload, pages: List[int]) -> None:
        """Install a migrated page run. v2 payloads (device arrays or the
        layer-chunked ``{"chunks": [...]}`` a MigrationHandle.wait() yields)
        go through the donated jit'd scatter; v1 host payloads keep the
        un-jitted full-pool rewrite for benchmark comparison."""
        if payload.get("host_gather"):
            idx = jnp.asarray(pages[:payload["k"].shape[1]], jnp.int32)
            self.pool.k = self.pool.k.at[:, idx].set(jnp.asarray(payload["k"]))
            self.pool.v = self.pool.v.at[:, idx].set(jnp.asarray(payload["v"]))
            self.pool.full_pool_copies += 2          # k and v each rewritten
            return
        chunks = payload.get("chunks")
        if chunks is None:
            chunks = [(0, payload["k"], payload["v"])]
        # the run covers the pages allocated at import time; a lazy (overlap)
        # import may fire after _ensure_pages appended the next decode page
        pages = pages[:chunks[0][1].shape[1]]
        target = self.pool.run_sharding()
        for l0, k_run, v_run in chunks:
            # no-op when DistFlow already resharded onto this mesh; real
            # placement change only for payloads that skipped transfer_sharded
            k_run = jax.device_put(k_run, target)
            v_run = jax.device_put(v_run, target)
            self.pool.scatter_run(pages, k_run, v_run, layer_start=l0)


# ===========================================================================
# Prefill microkernel
# ===========================================================================


class PagedPrefillRunner:
    def __init__(self, rt: PagedRunner):
        self.rt = rt
        self._prefill_fns: Dict[Tuple[int, int], Any] = {}
        # batched ragged prefill jits, keyed (token_bucket, page_bucket,
        # n_rows) — all pow2/static, so a warmed engine never recompiles.
        self._ragged_fns: Dict[Tuple[int, int, int], Any] = {}

    # ------------------------------------------------- legacy per-sequence
    def prefill_chunk(self, seq: SequenceState, chunk_tokens: List[int]
                      ) -> Optional[jax.Array]:
        """Run one prompt chunk; returns last-token logits when this chunk
        completes the prompt (so the engine can sample the first token)."""
        rt = self.rt
        c = len(chunk_tokens)
        start = seq.n_cached
        npages = len(seq.pages)
        fn = self._prefill_fn(c, npages)
        tokens = jnp.asarray(chunk_tokens, jnp.int32)[None]
        bt = jnp.asarray(seq.pages, jnp.int32)[None]
        logits, rt.pool.k, rt.pool.v = fn(
            rt.params, tokens, jnp.asarray([start], jnp.int32), bt,
            rt.pool.k, rt.pool.v)
        seq.n_cached = start + c
        if seq.n_cached >= seq.n_prompt:
            return logits[0]
        return None

    def _prefill_fn(self, c: int, npages: int):
        key = (c, npages)
        if key in self._prefill_fns:
            return self._prefill_fns[key]
        self.rt.prefill_jit_compiles += 1
        rt = self.rt
        cfg = rt.cfg
        wins = rt._wins
        ps = rt.pool.page_size

        def run(params, tokens, start, bt, k_pool, v_pool):
            x = T.embed(cfg, params, tokens)                    # (1,C,D)
            positions = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None]
            flat = start[0] + jnp.arange(c)
            page = bt[0, flat // ps]
            slot = flat % ps
            total = npages * ps
            kpos_base = jnp.arange(total, dtype=jnp.int32)[None]
            for li in range(cfg.n_layers):
                p = jax.tree.map(lambda a: a[li], params["blocks"])
                h = L.apply_norm(x, p["ln1"], cfg.norm)
                q, k_new, v_new = L.attn_qkv(p["attn"], h, cfg.n_heads,
                                             cfg.n_kv_heads, cfg.head_dim,
                                             positions, cfg.rope_theta, cfg.qk_norm)
                k_pool = k_pool.at[li, page, slot].set(k_new[0])
                v_pool = v_pool.at[li, page, slot].set(v_new[0])
                k_seq = k_pool[li, bt[0]].reshape(1, total, cfg.n_kv_heads, cfg.head_dim)
                v_seq = v_pool[li, bt[0]].reshape(1, total, cfg.n_kv_heads, cfg.head_dim)
                kpos = jnp.where(kpos_base < (start[0] + c), kpos_base,
                                 T.GLOBAL_WINDOW + 1)
                mask = L.causal_mask(positions, kpos)
                mask &= kpos[:, None, :] > (positions[:, :, None] - wins[li])
                o = L.attention(q, k_seq, v_seq, mask, cfg.attn_logit_softcap)
                x = x + S._post_attn(cfg, p, L.attn_out(p["attn"], o))
                h = L.apply_norm(x, p["ln2"], cfg.norm)
                if "moe" in p:
                    from repro.models import moe as M
                    m = M.moe_apply(p["moe"], h, cfg.moe, cfg.mlp_act, groups=1)
                else:
                    m = L.mlp_apply(p["mlp"], h, cfg.mlp_act)
                if cfg.post_norms:
                    m = L.apply_norm(m, p["ln2_post"], cfg.norm)
                x = x + m
            logits = T.unembed(cfg, params, x[:, -1:])[:, 0]
            return logits, k_pool, v_pool

        run = rt._jit_step(run, donate=(4, 5))
        self._prefill_fns[key] = run
        return run

    # ------------------------------------------------- batched ragged
    def prefill_ragged(self, tokens, positions, pages, slots, bt_tok,
                       final_idx, temps, top_ps, key):
        """ONE dispatch for the whole step's prefill plan (DESIGN.md §12).

        Packed operands (host-built by the engine):
          tokens/positions/pages/slots  (Tb,)    flat ragged token stream;
                                                 padding tokens point at the
                                                 pool's scratch page, slot 0,
                                                 position 0
          bt_tok                        (Tb, Pb) per-TOKEN block-table row
                                                 (its sequence's pages,
                                                 scratch-padded) — keying on
                                                 the per-token table keeps
                                                 the jit key free of the
                                                 batch composition
          final_idx                     (Sb,)    flat index of each entry's
                                                 chunk-final token
          temps/top_ps                  (Sb,)    per-entry sampling params
        Returns (logits (Sb, Vp), sampled tokens (Sb,), chained PRNG key);
        row i is entries[i]'s chunk-final position. The pools update in
        place (donated)."""
        rt = self.rt
        tb = int(tokens.shape[0])
        pb = int(bt_tok.shape[1])
        sb = int(final_idx.shape[0])
        fn = self._ragged_fn(tb, pb, sb)
        logits, toks, key, rt.pool.k, rt.pool.v = fn(
            rt.params, tokens, positions, pages, slots, bt_tok, final_idx,
            temps, top_ps, key, rt.pool.k, rt.pool.v)
        return logits, toks, key

    def _ragged_fn(self, tb: int, pb: int, sb: int):
        key_t = (tb, pb, sb)
        fn = self._ragged_fns.get(key_t)
        if fn is not None:
            return fn
        self.rt.prefill_jit_compiles += 1
        rt = self.rt
        cfg = rt.cfg
        wins = rt._wins
        ps = rt.pool.page_size
        total = pb * ps
        from repro.engine.sampling import greedy_core, sample_core

        def run(params, tokens, positions, page, slot, bt_tok, final_idx,
                temps, top_ps, key, k_pool, v_pool):
            # every packed token is its own batch row (Tb, 1, D): queries are
            # per-token, keys are the token's own page run gathered via its
            # block-table row — sequences never see each other's pages.
            x = T.embed(cfg, params, tokens[:, None])           # (Tb,1,D)
            pos2 = positions[:, None]                           # (Tb,1)
            kpos_base = jnp.arange(total, dtype=jnp.int32)[None]
            # slot j of a gathered run holds its sequence's token j; slots
            # past the token's own position are either unwritten or another
            # step's future — one causal mask covers both. Padding rows
            # (position 0) attend only to their scratch slot.
            kpos = jnp.where(kpos_base <= pos2, kpos_base,
                             T.GLOBAL_WINDOW + 1)               # (Tb,total)
            for li in range(cfg.n_layers):
                p = jax.tree.map(lambda a: a[li], params["blocks"])
                h = L.apply_norm(x, p["ln1"], cfg.norm)
                q, k_new, v_new = L.attn_qkv(p["attn"], h, cfg.n_heads,
                                             cfg.n_kv_heads, cfg.head_dim,
                                             pos2, cfg.rope_theta,
                                             cfg.qk_norm)
                # ONE scatter of the whole step's fresh KV, all sequences at
                # once; chunk-internal attention works because the scatter
                # precedes the gather within the layer.
                k_pool = k_pool.at[li, page, slot].set(k_new[:, 0])
                v_pool = v_pool.at[li, page, slot].set(v_new[:, 0])
                k_seq = k_pool[li, bt_tok].reshape(tb, total, cfg.n_kv_heads,
                                                   cfg.head_dim)
                v_seq = v_pool[li, bt_tok].reshape(tb, total, cfg.n_kv_heads,
                                                   cfg.head_dim)
                mask = L.causal_mask(pos2, kpos)
                mask &= kpos[:, None, :] > (pos2[:, :, None] - wins[li])
                o = L.attention(q, k_seq, v_seq, mask, cfg.attn_logit_softcap)
                x = x + S._post_attn(cfg, p, L.attn_out(p["attn"], o))
                h = L.apply_norm(x, p["ln2"], cfg.norm)
                if "moe" in p:
                    from repro.models import moe as M
                    m = M.moe_apply(p["moe"], h, cfg.moe, cfg.mlp_act,
                                    groups=1)
                else:
                    m = L.mlp_apply(p["mlp"], h, cfg.mlp_act)
                if cfg.post_norms:
                    m = L.apply_norm(m, p["ln2_post"], cfg.norm)
                x = x + m
            # unembed ONLY the chunk-final rows — (Sb, Vp), not (Tb, Vp)
            logits = T.unembed(cfg, params, x[final_idx])[:, 0]
            key, sub = jax.random.split(key)
            all_greedy = jnp.all(temps <= 0.0)
            toks = jax.lax.cond(
                all_greedy,
                lambda lg: greedy_core(lg, cfg.vocab_size),
                lambda lg: sample_core(lg, temps, top_ps, sub,
                                       cfg.vocab_size),
                logits)
            return logits, toks, key, k_pool, v_pool

        if rt.mesh is None:
            fn = jax.jit(run, donate_argnums=(10, 11))
        else:
            r, kv = rt._repl, rt._kv_sh
            fn = jax.jit(run, donate_argnums=(10, 11),
                         in_shardings=(rt._param_sh, r, r, r, r, r, r, r, r,
                                       r, kv, kv),
                         out_shardings=(r, r, r, kv, kv))
        self._ragged_fns[key_t] = fn
        return fn

    def warmup_ragged(self, token_buckets, page_buckets, n_rows: int) -> int:
        """Precompile the batched-prefill jit grid ahead of serving (the
        prefill twin of ``warmup_fused``): every token bucket × every page
        bucket at the engine's fixed row count. Runs each combination once
        against a transient throwaway KV pool (donated and chained
        call-to-call). Returns the number of executables compiled."""
        rt = self.rt
        k = jnp.zeros_like(rt.pool.k)
        v = jnp.zeros_like(rt.pool.v)
        if rt.mesh is not None:
            k = jax.device_put(k, rt._kv_sh)
            v = jax.device_put(v, rt._kv_sh)
        key = jax.random.PRNGKey(0)
        n = 0
        for tb in sorted(set(token_buckets)):
            for pb in sorted(set(page_buckets)):
                fn = self._ragged_fn(tb, pb, n_rows)
                _, _, key, k, v = fn(
                    rt.params, jnp.zeros((tb,), jnp.int32),
                    jnp.zeros((tb,), jnp.int32), jnp.zeros((tb,), jnp.int32),
                    jnp.zeros((tb,), jnp.int32),
                    jnp.zeros((tb, pb), jnp.int32),
                    jnp.zeros((n_rows,), jnp.int32),
                    jnp.zeros((n_rows,), jnp.float32),
                    jnp.ones((n_rows,), jnp.float32), key, k, v)
                n += 1
        jax.block_until_ready(k)
        return n


# ===========================================================================
# Decode microkernel (the hot loop of DESIGN.md §8)
# ===========================================================================


class PagedDecodeRunner:
    def __init__(self, rt: PagedRunner):
        self.rt = rt
        self._decode_fns: Dict[int, Any] = {}
        # bucketed fused decode+sample jits, keyed (k_steps, batch_bucket,
        # page_bucket); misses count into the facade's jit_compiles.
        self._fused_fns: Dict[Tuple[int, int, int], Any] = {}

    def decode(self, seqs: List[SequenceState]) -> jax.Array:
        """One decode step for a batch of sequences. The new token of each
        seq is seqs[i].tokens[-1]; KV is written at position len(tokens)-1.
        Caller must have appended a page if needed."""
        rt = self.rt
        b = len(seqs)
        maxp = max(len(s.pages) for s in seqs)
        bt = np.zeros((b, maxp), np.int32)
        for i, s in enumerate(seqs):
            bt[i, :len(s.pages)] = s.pages
        tokens = jnp.asarray([s.tokens[-1] for s in seqs], jnp.int32)
        lengths = jnp.asarray([len(s.tokens) for s in seqs], jnp.int32)
        fn = self._decode_fn(maxp)
        logits, rt.pool.k, rt.pool.v = fn(
            rt.params, tokens, jnp.asarray(bt), lengths, rt.pool.k, rt.pool.v)
        for s in seqs:
            s.n_cached = len(s.tokens)
        return logits

    def _decode_body(self, params, tokens, bt, lengths, k_pool, v_pool):
        """Traceable single decode step: (B,) token ids + device metadata →
        (B, Vp) logits + updated pools. Shared by the legacy per-step jit and
        the fused decode+sample horizon (DESIGN.md §8)."""
        rt = self.rt
        cfg = rt.cfg
        wins = rt._wins
        ps = rt.pool.page_size
        b = tokens.shape[0]
        x = T.embed(cfg, params, tokens[:, None])
        pos = (lengths - 1)[:, None]
        bidx = jnp.arange(b)
        page = bt[bidx, (lengths - 1) // ps]
        slot = (lengths - 1) % ps
        for li in range(cfg.n_layers):
            p = jax.tree.map(lambda a: a[li], params["blocks"])
            h = L.apply_norm(x, p["ln1"], cfg.norm)
            q, k_new, v_new = L.attn_qkv(p["attn"], h, cfg.n_heads,
                                         cfg.n_kv_heads, cfg.head_dim,
                                         pos, cfg.rope_theta, cfg.qk_norm)
            k_pool = k_pool.at[li, page, slot].set(k_new[:, 0])
            v_pool = v_pool.at[li, page, slot].set(v_new[:, 0])
            win = wins[li] if wins[li] < T.GLOBAL_WINDOW else None
            o = KREF.paged_attention_ref(q[:, 0], k_pool[li], v_pool[li],
                                         bt, lengths,
                                         softcap=cfg.attn_logit_softcap,
                                         window=win)
            x = x + S._post_attn(cfg, p, L.attn_out(p["attn"], o[:, None]))
            h = L.apply_norm(x, p["ln2"], cfg.norm)
            if "moe" in p:
                from repro.models import moe as M
                m = M.moe_apply(p["moe"], h, cfg.moe, cfg.mlp_act, groups=1)
            else:
                m = L.mlp_apply(p["mlp"], h, cfg.mlp_act)
            if cfg.post_norms:
                m = L.apply_norm(m, p["ln2_post"], cfg.norm)
            x = x + m
        logits = T.unembed(cfg, params, x)[:, 0]
        return logits, k_pool, v_pool

    def _decode_fn(self, maxp: int):
        if maxp in self._decode_fns:
            return self._decode_fns[maxp]
        self.rt.jit_compiles += 1

        def step(params, tokens, bt, lengths, k_pool, v_pool):
            return self._decode_body(params, tokens, bt, lengths,
                                     k_pool, v_pool)

        step = self.rt._jit_step(step, donate=(4, 5))
        self._decode_fns[maxp] = step
        return step

    # ---------------------------------------------- fused decode hot loop
    def decode_fused(self, state, k_steps: int) -> jax.Array:
        """NPU-centric decode (DESIGN.md §8): run ``k_steps`` decode+sample
        iterations as ONE device dispatch over the persistent device-resident
        batch state. Sampling is fused into the step — logits never leave the
        device — and the carried metadata (lengths, last tokens, PRNG key)
        advances in-jit, so the host's only job is this dispatch. Returns the
        (k_steps, batch_bucket) sampled-token block WITHOUT materializing it
        on the host; the caller fetches it asynchronously a horizon later."""
        rt = self.rt
        fn = self._decode_fused_fn(k_steps, state.bb, state.pb)
        (toks, state.key, state.last_tok, state.lengths,
         rt.pool.k, rt.pool.v) = fn(
            rt.params, state.bt, state.active, state.temps, state.top_ps,
            state.key, state.last_tok, state.lengths,
            rt.pool.k, rt.pool.v)
        return toks

    def _decode_fused_fn(self, k_steps: int, bb: int, pb: int):
        key_t = (k_steps, bb, pb)
        fn = self._fused_fns.get(key_t)
        if fn is not None:
            return fn
        rt = self.rt
        rt.jit_compiles += 1
        cfg = rt.cfg
        from repro.engine.sampling import greedy_core, sample_core

        def horizon(params, bt, active, temps, top_ps, key, last_tok,
                    lengths, k_pool, v_pool):
            act = active.astype(jnp.int32)
            # the all-greedy shortcut v1's sample_batch takes on the host,
            # moved in-jit: one traced predicate selects pure argmax over the
            # full top-p pipeline at runtime (per-row results are identical)
            all_greedy = jnp.all(temps <= 0.0)

            def one(carry, _):
                key, last_tok, lengths, k_pool, v_pool = carry
                logits, k_pool, v_pool = self._decode_body(
                    params, last_tok, bt, lengths, k_pool, v_pool)
                key, sub = jax.random.split(key)
                toks = jax.lax.cond(
                    all_greedy,
                    lambda lg: greedy_core(lg, cfg.vocab_size),
                    lambda lg: sample_core(lg, temps, top_ps, sub,
                                           cfg.vocab_size),
                    logits)
                # padding rows: freeze token + length so their KV write stays
                # parked at slot 0 of the pool's scratch page forever
                toks = jnp.where(active, toks, last_tok)
                return (key, toks, lengths + act, k_pool, v_pool), toks

            (key, last_tok, lengths, k_pool, v_pool), toks = jax.lax.scan(
                one, (key, last_tok, lengths, k_pool, v_pool), None,
                length=k_steps)
            return toks, key, last_tok, lengths, k_pool, v_pool

        if rt.mesh is None:
            fn = jax.jit(horizon, donate_argnums=(8, 9))
        else:
            r, kv = rt._repl, rt._kv_sh
            fn = jax.jit(horizon, donate_argnums=(8, 9),
                         in_shardings=(rt._param_sh, r, r, r, r, r, r, r,
                                       kv, kv),
                         out_shardings=(r, r, r, r, kv, kv))
        self._fused_fns[key_t] = fn
        return fn

    def warmup_fused(self, batch_buckets, page_buckets, horizons) -> int:
        """Precompile the bucketed fused decode jits ahead of serving (the
        §4.2 warmup pass) so steady state never recompiles. Runs each bucket
        combination once against a transient throwaway KV pool (donated and
        chained call-to-call, so the warmup never touches live pages and
        peaks at one extra pool copy). Returns the number of executables
        compiled. Note: ``jit.lower().compile()`` does NOT seed the dispatch
        cache on this jax version, so the warmup must really call."""
        rt = self.rt
        k = jnp.zeros_like(rt.pool.k)
        v = jnp.zeros_like(rt.pool.v)
        if rt.mesh is not None:
            k = jax.device_put(k, rt._kv_sh)
            v = jax.device_put(v, rt._kv_sh)
        key = jax.random.PRNGKey(0)
        n = 0
        for k_steps in sorted(set(horizons)):
            for bb in sorted(set(batch_buckets)):
                for pb in sorted(set(page_buckets)):
                    fn = self._decode_fused_fn(k_steps, bb, pb)
                    _, key, _, _, k, v = fn(
                        rt.params, jnp.zeros((bb, pb), jnp.int32),
                        jnp.zeros((bb,), bool), jnp.zeros((bb,), jnp.float32),
                        jnp.ones((bb,), jnp.float32), key,
                        jnp.zeros((bb,), jnp.int32),
                        jnp.ones((bb,), jnp.int32), k, v)
                    n += 1
        jax.block_until_ready(k)
        return n
