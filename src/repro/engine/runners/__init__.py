"""Runner-family registry package (DESIGN.md §12).

Importing this package registers the built-in families in match order:

  * ``paged`` — attention-only towers (global / swa / local_global, no
    modality encoders): paged-KV continuous batching with the batched
    ragged prefill + fused decode microkernels.
  * ``slot``  — everything else (recurrent / hybrid / cross-attention):
    fixed batch slots with dense per-slot caches; registered last with an
    always-true predicate, so it is the fallback.

New families register through ``register_family`` without touching the
engine: FLOWSERVE resolves them via ``resolve_family(cfg)``.
"""
from repro.engine.runners.base import (RunnerFamily,  # noqa: F401
                                       SequenceState, families, pick_runner,
                                       register_family, resolve_family)
from repro.engine.runners.paged import PagedRunner  # noqa: F401
from repro.engine.runners.slot import SlotRunner  # noqa: F401
from repro.launch.sharding import engine_kv_pool_sharding


def _paged_matches(cfg) -> bool:
    return (cfg.attn_kind in ("global", "swa", "local_global")
            and cfg.vision is None and cfg.encoder is None)


register_family(RunnerFamily(
    name="paged",
    runner_cls=PagedRunner,
    matches=_paged_matches,
    uses_pages=True,
    kv_pool_sharding=engine_kv_pool_sharding,
))

register_family(RunnerFamily(
    name="slot",
    runner_cls=SlotRunner,
    matches=lambda cfg: True,
    uses_pages=False,
))
