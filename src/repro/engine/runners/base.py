"""Runner-family registry (DESIGN.md §12, the paper's "microkernel" FLOWSERVE).

The model zoo does not share one execution strategy: attention-only towers
batch through a paged KV pool, recurrent/hybrid/cross-attention families
batch through fixed per-slot dense caches. Before this registry the engine
special-cased the split ad hoc (``pick_runner`` string compares in
``model_runner.py`` / ``flowserve.py``). Now each family is a registered
``RunnerFamily``: a predicate over ``ModelConfig``, the runner class that
executes it, and the family's sharding hooks — FLOWSERVE resolves the
family once at engine construction and every later decision (pool vs
slots, KV-pool sharding, fused-prefill/fused-decode support) is a method
on the family, not an if-ladder in the engine.

Each family's runner is itself split per phase — a ``*PrefillRunner`` and a
``*DecodeRunner`` microkernel pair behind one facade — so workload features
(batched ragged prefill, fused decode+sample horizons, later constrained
decoding / speculative verify) land in exactly one phase runner without
touching the other.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.configs.base import ModelConfig


@dataclass
class SequenceState:
    seq_id: str
    tokens: List[int]                   # full token ids (prompt + generated)
    n_prompt: int
    n_cached: int = 0                   # tokens with KV/state materialized
    pages: List[int] = field(default_factory=list)
    reused_pages: int = 0               # prefix-cache pages (shared, pinned)
    slot: Optional[int] = None          # SlotRunner slot id
    state: Any = None                   # SlotRunner per-seq state snapshot
    extra: Dict[str, Any] = field(default_factory=dict)  # modality stubs


@dataclass(frozen=True)
class RunnerFamily:
    """One entry in the microkernel registry.

    ``matches`` decides whether this family executes a given model config;
    families are tried in registration order, so the fallback family
    registers last with an always-true predicate. ``uses_pages`` selects the
    engine's KV data plane (paged pool + RTC prefix cache vs dense slot
    caches + state checkpoints); ``kv_pool_sharding`` is the family's TP
    placement rule for that plane (None ⇒ the family has no paged pool).
    """
    name: str
    runner_cls: type
    matches: Callable[[ModelConfig], bool]
    uses_pages: bool
    kv_pool_sharding: Optional[Callable[[ModelConfig, Any], Any]] = None

    def build(self, bundle, params, pool=None, *, dtype, mesh=None, **kw):
        """Construct the family's runner (the facade over its prefill/decode
        pair). Paged families take the engine's page pool; slot families
        take slot geometry via ``kw``."""
        if self.uses_pages:
            return self.runner_cls(bundle, params, pool, dtype, mesh=mesh,
                                   **kw)
        return self.runner_cls(bundle, params, dtype=dtype, mesh=mesh, **kw)


_FAMILIES: List[RunnerFamily] = []


def register_family(family: RunnerFamily) -> RunnerFamily:
    """Append a family to the registry (order = match priority). Replaces a
    same-named entry in place so reloads / test doubles stay idempotent."""
    for i, f in enumerate(_FAMILIES):
        if f.name == family.name:
            _FAMILIES[i] = family
            return family
    _FAMILIES.append(family)
    return family


def resolve_family(cfg: ModelConfig) -> RunnerFamily:
    """First registered family whose predicate accepts ``cfg``."""
    for fam in _FAMILIES:
        if fam.matches(cfg):
            return fam
    raise LookupError(
        f"no runner family matches model {getattr(cfg, 'name', cfg)!r}")


def families() -> List[RunnerFamily]:
    return list(_FAMILIES)


def pick_runner(cfg: ModelConfig) -> str:
    """Family NAME for a config — the legacy string API, now a registry
    lookup (kept because tests and the serving plane key on the string)."""
    return resolve_family(cfg).name
