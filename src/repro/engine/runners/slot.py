"""Slot runner family: recurrent / hybrid / cross-attention towers (rwkv6,
recurrentgemma, seamless enc-dec, llama-vision) batching through fixed
per-slot dense caches (their state is O(1) or includes modality memories).
Continuous batching assigns sequences to free slots; prefix reuse is
state-checkpoint based (DESIGN.md §4).

``SlotRunner`` is the family facade over the phase pair (DESIGN.md §12):

  * ``SlotPrefillRunner`` — chunked prefill through ``serving.prefill``.
    Chunk lengths are pow2-bucketed with a masked tail (``n_valid`` threads
    through the model stack: pad steps are exact identities for the
    recurrences, causally masked for attention layers), so arbitrary prompt
    shapes share O(log max_chunk) jit executables instead of minting one
    per raw length. ``bucket_prefill=False`` keeps the raw-length path for
    parity testing.
  * ``SlotDecodeRunner`` — all-slot batched decode, plus ``decode_sample``:
    decode + ``sampling.sample_core`` fused into ONE dispatch (the slot
    twin of the paged fused hot loop), so logits never reach the host.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.engine.hotloop import pow2_bucket
from repro.engine.runners.base import SequenceState
from repro.launch import sharding as SH
from repro.models import serving as S
from repro.models.model_factory import ModelBundle


class SlotRunner:
    """Family facade: slot bookkeeping + dense caches + phase delegation
    (public API of the pre-registry SlotRunner, preserved verbatim)."""

    def __init__(self, bundle: ModelBundle, params, n_slots: int, max_len: int,
                 dtype=jnp.float32, mesh=None, bucket_prefill: bool = True):
        self.bundle = bundle
        self.cfg = bundle.cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.dtype = dtype
        self.mesh = mesh
        self.bucket_prefill = bucket_prefill
        cache = bundle.init_cache(n_slots, max_len, dtype)
        if mesh is not None:
            # SPMD TE: weights + dense per-slot caches shard per
            # launch/sharding.py (k/v shard the sequence dim over the mesh;
            # recurrent state shards its width/head dims where divisible).
            self._param_sh = SH.engine_param_shardings(self.cfg, params, mesh)
            self._cache_sh = SH.engine_cache_shardings(self.cfg, cache, mesh,
                                                       n_slots, max_len)
            self._repl = NamedSharding(mesh, P())
            params = jax.device_put(params, self._param_sh)
            cache = jax.device_put(cache, self._cache_sh)
        self.params = params
        self.cache = cache
        self.free_slots = list(range(n_slots))
        self.jit_compiles = 0            # decode-path cache misses
        self.prefill_jit_compiles = 0    # prefill-path cache misses
        self.prefill = SlotPrefillRunner(self)
        self.decoder = SlotDecodeRunner(self)

    # batch-dim axis for every cache leaf except `length`
    def _slot_slice(self, slot: int):
        def f(path, a):
            if path == "length":
                return a[slot:slot + 1]
            return a[:, slot:slot + 1]
        return {k: f(k, v) for k, v in self.cache.items()}

    def _slot_write(self, slot: int, sub):
        for k, v in sub.items():
            if k == "length":
                self.cache[k] = self.cache[k].at[slot].set(v[0])
            else:
                self.cache[k] = self.cache[k].at[:, slot].set(v[:, 0])

    def alloc_slot(self, seq: SequenceState) -> bool:
        if not self.free_slots:
            return False
        seq.slot = self.free_slots.pop()
        # reset slot length AND recurrent/conv state — stale KV is masked by
        # length, but recurrent state would leak the previous occupant.
        self.cache["length"] = self.cache["length"].at[seq.slot].set(0)
        for key in ("state", "last_tm", "last_cm", "h", "conv"):
            if key in self.cache:
                self.cache[key] = self.cache[key].at[:, seq.slot].set(0)
        return True

    def free_slot(self, seq: SequenceState) -> None:
        if seq.slot is not None:
            self.free_slots.append(seq.slot)
            seq.slot = None

    # phase delegation
    def prefill_chunk(self, seq: SequenceState, chunk_tokens: List[int]
                      ) -> Optional[jax.Array]:
        return self.prefill.prefill_chunk(seq, chunk_tokens)

    def decode(self, seqs: List[SequenceState]) -> jax.Array:
        return self.decoder.decode(seqs)

    def decode_sample(self, seqs: List[SequenceState], temps, top_ps, key):
        return self.decoder.decode_sample(seqs, temps, top_ps, key)

    # state checkpointing (prefix cache for recurrent archs)
    def snapshot_state(self, seq: SequenceState):
        sub = self._slot_slice(seq.slot)
        return jax.tree.map(np.asarray, sub)

    def restore_state(self, seq: SequenceState, snap) -> None:
        self._slot_write(seq.slot, jax.tree.map(jnp.asarray, snap))
        seq.n_cached = int(snap["length"][0])

    def export_kv(self, seq: SequenceState):
        return {"state": self.snapshot_state(seq), "tokens": list(seq.tokens),
                "n_prompt": seq.n_prompt, "n_cached": seq.n_cached}

    def import_kv(self, payload, seq: SequenceState) -> None:
        self.restore_state(seq, payload["state"])


# ===========================================================================
# Prefill microkernel
# ===========================================================================


class SlotPrefillRunner:
    def __init__(self, rt: SlotRunner):
        self.rt = rt
        # jits keyed on the pow2 chunk bucket (raw length with
        # bucket_prefill=False) — n_valid rides as a traced operand so one
        # executable serves every real length within the bucket.
        self._prefill_jits: Dict[int, Any] = {}

    def prefill_chunk(self, seq: SequenceState, chunk_tokens: List[int]
                      ) -> Optional[jax.Array]:
        rt = self.rt
        c = len(chunk_tokens)
        cb = pow2_bucket(c) if rt.bucket_prefill else c
        sub = rt._slot_slice(seq.slot)
        fn = self._prefill_fn(cb)
        extra = {k: jnp.asarray(v) for k, v in seq.extra.items()}
        toks = np.zeros((1, cb), np.int32)
        toks[0, :c] = chunk_tokens
        logits, sub = fn(rt.params, jnp.asarray(toks), sub, extra,
                         jnp.int32(c))
        rt._slot_write(seq.slot, sub)
        seq.n_cached += c
        if seq.n_cached >= seq.n_prompt:
            return logits[0]
        return None

    def _prefill_fn(self, cb: int):
        if cb in self._prefill_jits:
            return self._prefill_jits[cb]
        self.rt.prefill_jit_compiles += 1
        rt = self.rt
        cfg = rt.cfg

        def run(params, tokens, cache, extra, n_valid):
            return S.prefill(cfg, params, tokens, cache, n_valid=n_valid,
                             **extra)

        if rt.mesh is not None:
            # `extra` (modality stubs) replicates: a single sharding works as
            # a pytree prefix over the whole dict.
            run = jax.jit(run, in_shardings=(rt._param_sh, rt._repl,
                                             rt._cache_sh, rt._repl, rt._repl),
                          out_shardings=(rt._repl, rt._cache_sh))
        else:
            run = jax.jit(run)
        self._prefill_jits[cb] = run
        return run


# ===========================================================================
# Decode microkernel
# ===========================================================================


class SlotDecodeRunner:
    def __init__(self, rt: SlotRunner):
        self.rt = rt
        cfg = rt.cfg
        if rt.mesh is not None:
            self._decode_jit = jax.jit(
                lambda p, t, c: S.decode_step(cfg, p, t, c),
                in_shardings=(rt._param_sh, rt._repl, rt._cache_sh),
                out_shardings=(rt._repl, rt._cache_sh))
        else:
            self._decode_jit = jax.jit(
                lambda p, t, c: S.decode_step(cfg, p, t, c))
        self._decode_sample_jit = None

    def decode(self, seqs: List[SequenceState]) -> jax.Array:
        """Decode all active slots in one batched step; returns logits rows
        aligned with `seqs` order."""
        rt = self.rt
        tokens = np.zeros((rt.n_slots,), np.int32)
        for s in seqs:
            tokens[s.slot] = s.tokens[-1]
        logits, rt.cache = self._decode_jit(rt.params, jnp.asarray(tokens),
                                            rt.cache)
        for s in seqs:
            s.n_cached = len(s.tokens)
        return logits[jnp.asarray([s.slot for s in seqs])]

    def decode_sample(self, seqs: List[SequenceState], temps, top_ps, key):
        """Decode + sample fused into ONE dispatch over all slots (ROADMAP
        carried follow-up: the SlotRunner sampling path, now through
        ``sampling.sample_core`` in-jit). ``temps``/``top_ps`` are
        (n_slots,) arrays indexed by SLOT (inactive slots greedy). Returns
        ((n_slots,) device token vector, chained PRNG key) — the caller
        gathers its live rows by slot, so logits never reach the host."""
        rt = self.rt
        tokens = np.zeros((rt.n_slots,), np.int32)
        for s in seqs:
            tokens[s.slot] = s.tokens[-1]
        fn = self._sample_fn()
        toks, rt.cache, key = fn(rt.params, jnp.asarray(tokens), rt.cache,
                                 jnp.asarray(temps), jnp.asarray(top_ps), key)
        for s in seqs:
            s.n_cached = len(s.tokens)
        return toks, key

    def _sample_fn(self):
        if self._decode_sample_jit is not None:
            return self._decode_sample_jit
        self.rt.jit_compiles += 1
        rt = self.rt
        cfg = rt.cfg
        from repro.engine.sampling import greedy_core, sample_core

        def run(params, tokens, cache, temps, top_ps, key):
            logits, cache = S.decode_step(cfg, params, tokens, cache)
            key, sub = jax.random.split(key)
            all_greedy = jnp.all(temps <= 0.0)
            toks = jax.lax.cond(
                all_greedy,
                lambda lg: greedy_core(lg, cfg.vocab_size),
                lambda lg: sample_core(lg, temps, top_ps, sub,
                                       cfg.vocab_size),
                logits)
            return toks, cache, key

        if rt.mesh is not None:
            r = rt._repl
            fn = jax.jit(run, in_shardings=(rt._param_sh, r, rt._cache_sh,
                                            r, r, r),
                         out_shardings=(r, rt._cache_sh, r))
        else:
            fn = jax.jit(run)
        self._decode_sample_jit = fn
        return fn
