"""Device-resident decode-batch state for the NPU-centric hot loop
(DESIGN.md §8, PAPER §4.2 / Figure 3).

The per-step host work of v1 decode — rebuilding the block table as a fresh
``np.zeros``, re-materializing lengths / last tokens / sampling params, and
blocking on the sampled ids — is replaced by ONE persistent set of device
arrays that the fused decode jit carries forward:

  * ``bt``       (Bb, Pb) int32 — bucketed block table; padding entries point
                 at the pool's pinned scratch page so padded rows write KV
                 into a sink nothing reads.
  * ``lengths``  (Bb,) int32 — advanced IN-JIT each decode step.
  * ``last_tok`` (Bb,) int32 — the fused sampler's output feeds the next
                 step's embedding without leaving the device.
  * ``active``   (Bb,) bool — real rows vs bucket padding.
  * ``temps``/``top_ps`` (Bb,) f32 — per-row sampling params, written once
                 when a sequence joins the batch.
  * ``key``      — the PRNG key, split in-jit one step at a time.

Buckets are powers of two (batch and page-count), so steady-state serving
reuses a small, precompilable set of jit cache keys. Batch events — a
sequence joining after prefill, leaving on finish/preempt, or growing a
page — are applied as incremental scatter updates; a step with no event
costs the host NOTHING but the single fused dispatch. Bucket growth (or an
engine-declared ``reset``) rebuilds every row from host-authoritative
values; the engine drains in-flight horizons first so host and device
agree.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, List[int], int, int, float, float]
#     (seq_id, pages, length, last_tok, temperature, top_p)


def pow2_bucket(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    return 1 << max(0, int(n) - 1).bit_length()


def pow2s(cap: int) -> List[int]:
    """Every power-of-two bucket up to (and including) pow2_bucket(cap) —
    the jit keys a batch ramping from 1 to ``cap`` will visit."""
    out, b = [], 1
    while b <= pow2_bucket(max(1, cap)):
        out.append(b)
        b *= 2
    return out


class DecodeHotState:
    """Persistent on-device decode-batch metadata + host-side slot map."""

    def __init__(self, pool, sharding=None, key=None):
        self.pool = pool
        self.sharding = sharding            # replicated NamedSharding | None
        self.scratch = pool.scratch_page()  # padding rows' KV write sink
        self.bb = 0                         # batch bucket (rows)
        self.pb = 0                         # page bucket (block-table cols)
        self.seq_ids: List[Optional[str]] = []
        self.npages: List[int] = []
        self.slot_of: Dict[str, int] = {}
        self.bt = self.lengths = self.last_tok = None
        self.active = self.temps = self.top_ps = None
        self.key = key if key is not None else jax.random.PRNGKey(0)
        if sharding is not None:
            self.key = jax.device_put(self.key, sharding)
        self.event_dispatches = 0   # device scatters spent on batch events
        self.rebuilds = 0
        self._force_rebuild = True

    # ------------------------------------------------------------ helpers
    def _put(self, arr):
        self.event_dispatches += 1
        a = jnp.asarray(arr)
        return jax.device_put(a, self.sharding) if self.sharding is not None \
            else a

    def _ev(self):
        self.event_dispatches += 1

    def reset(self) -> None:
        """Declare the device rows stale (legacy-path decode ran, or a
        preemption fired): the next sync rebuilds every row from host
        values. The engine guarantees nothing is in flight by then."""
        self._force_rebuild = True

    def evict(self, seq_id: str) -> None:
        """Release a sequence's row NOW (the engine calls this on finish /
        release). sync()'s leave path only fires for ids that miss a later
        batch, so without an explicit evict a request id REUSED as the next
        batch's first member would alias the stale row — decoding with the
        old lengths/last-token and writing KV through a block table whose
        pages were already released. Deactivating the device row is safe
        with a horizon in flight: the dispatched block captured the old
        operands, and these scatters affect only future dispatches."""
        slot = self.slot_of.pop(seq_id, None)
        if slot is None:
            return
        self.seq_ids[slot] = None
        self.npages[slot] = 0
        self.active = self.active.at[slot].set(False); self._ev()
        self.lengths = self.lengths.at[slot].set(1); self._ev()
        self.bt = self.bt.at[slot, 0].set(self.scratch); self._ev()

    # ------------------------------------------------------------ planning
    def needs_rebuild(self, rows: List[Tuple[str, int]]) -> bool:
        """rows: (seq_id, n_pages). True when the next sync cannot be
        expressed as incremental scatters — bucket growth or a reset."""
        if self._force_rebuild or self.bb == 0:
            return True
        if pow2_bucket(len(rows)) > self.bb:
            return True
        return max(n for _, n in rows) > self.pb

    def oversized(self, rows: List[Tuple[str, int]]) -> bool:
        """rows: (seq_id, n_pages). True when either bucket is ≥2x what the
        batch needs — a shrink rebuild would pay for itself (padded rows
        cost real compute every step). The engine drains in-flight horizons
        to make the rebuild coherent, then syncs with can_shrink=True."""
        if self.bb == 0:
            return False
        return (pow2_bucket(len(rows)) <= self.bb // 2
                or pow2_bucket(max(n for _, n in rows)) <= self.pb // 2)

    # ------------------------------------------------------------ sync
    def sync(self, rows: List[Row], can_shrink: bool = False) -> int:
        """Reconcile the device state with the batch the engine is about to
        dispatch. Host-provided length/last_tok are honored only for JOINING
        rows (their pending count is zero by construction); existing rows'
        carried state is device-authoritative. Returns the number of device
        dispatches spent (0 in steady state).

        ``can_shrink=True`` (engine passes it when nothing is in flight, so
        the rebuild is free of drains) lets over-wide buckets from an earlier
        bigger batch snap back: ≥2x oversize on either axis triggers a
        rebuild at the exact power-of-two need, whose smaller jit key is
        already compiled from the way up. Without it a ramp-down batch would
        keep paying padded-row compute forever."""
        ev0 = self.event_dispatches
        rows2 = [(r[0], len(r[1])) for r in rows]
        if (can_shrink and self.oversized(rows2)) or self.needs_rebuild(rows2):
            self._rebuild(rows)
            return self.event_dispatches - ev0
        incoming = {r[0] for r in rows}
        leave = [i for i, sid in enumerate(self.seq_ids)
                 if sid is not None and sid not in incoming]
        if leave:
            for i in leave:
                del self.slot_of[self.seq_ids[i]]
                self.seq_ids[i] = None
                self.npages[i] = 0
            idx = jnp.asarray(leave, jnp.int32)
            self.active = self.active.at[idx].set(False); self._ev()
            self.lengths = self.lengths.at[idx].set(1); self._ev()
            # park the freed row's per-step KV write on the scratch sink
            self.bt = self.bt.at[idx, 0].set(self.scratch); self._ev()
        joins, extends = [], []
        for r in rows:
            slot = self.slot_of.get(r[0])
            if slot is None:
                joins.append(r)
            elif len(r[1]) != self.npages[slot]:
                extends.append((slot, r[1]))
        if joins:
            slots, bt_rows = [], []
            for sid, pages, *_ in joins:
                i = self.seq_ids.index(None)
                self.seq_ids[i] = sid
                self.npages[i] = len(pages)
                self.slot_of[sid] = i
                slots.append(i)
                row = np.full((self.pb,), self.scratch, np.int32)
                row[:len(pages)] = pages
                bt_rows.append(row)
            idx = jnp.asarray(slots, jnp.int32)
            self.bt = self.bt.at[idx].set(jnp.asarray(np.stack(bt_rows)))
            self._ev()
            self.lengths = self.lengths.at[idx].set(
                jnp.asarray([r[2] for r in joins], jnp.int32)); self._ev()
            self.last_tok = self.last_tok.at[idx].set(
                jnp.asarray([r[3] for r in joins], jnp.int32)); self._ev()
            self.active = self.active.at[idx].set(True); self._ev()
            self.temps = self.temps.at[idx].set(
                jnp.asarray([r[4] for r in joins], jnp.float32)); self._ev()
            self.top_ps = self.top_ps.at[idx].set(
                jnp.asarray([r[5] for r in joins], jnp.float32)); self._ev()
        if extends:
            # ALL page appends this step land in one scatter dispatch
            ridx, cidx, vals = [], [], []
            for slot, pages in extends:
                old = self.npages[slot]
                for c in range(old, len(pages)):
                    ridx.append(slot)
                    cidx.append(c)
                    vals.append(pages[c])
                self.npages[slot] = len(pages)
            self.bt = self.bt.at[jnp.asarray(ridx, jnp.int32),
                                 jnp.asarray(cidx, jnp.int32)].set(
                jnp.asarray(vals, jnp.int32)); self._ev()
        return self.event_dispatches - ev0

    # ------------------------------------------------------------ rebuild
    def _rebuild(self, rows: List[Row]) -> None:
        """Full row reconstruction from host-authoritative values (bucket
        growth, shrink, or reset) at the exact power-of-two buckets the
        batch needs. Old buckets' compiled jits stay cached, so revisiting
        a bucket never recompiles."""
        self._force_rebuild = False
        self.rebuilds += 1
        self.bb = pow2_bucket(len(rows))
        self.pb = pow2_bucket(max(len(r[1]) for r in rows))
        bt = np.full((self.bb, self.pb), self.scratch, np.int32)
        lengths = np.ones((self.bb,), np.int32)
        last_tok = np.zeros((self.bb,), np.int32)
        active = np.zeros((self.bb,), bool)
        temps = np.zeros((self.bb,), np.float32)
        top_ps = np.ones((self.bb,), np.float32)
        self.seq_ids = [None] * self.bb
        self.npages = [0] * self.bb
        self.slot_of = {}
        for i, (sid, pages, length, tok, temp, top_p) in enumerate(rows):
            self.seq_ids[i] = sid
            self.npages[i] = len(pages)
            self.slot_of[sid] = i
            bt[i, :len(pages)] = pages
            lengths[i] = length
            last_tok[i] = tok
            active[i] = True
            temps[i] = temp
            top_ps[i] = top_p
        self.bt = self._put(bt)
        self.lengths = self._put(lengths)
        self.last_tok = self._put(last_tok)
        self.active = self._put(active)
        self.temps = self._put(temps)
        self.top_ps = self._put(top_ps)
