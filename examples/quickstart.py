"""Quickstart — the end-to-end serving driver (the paper's kind: serving).

Boots one PD-colocated FLOWSERVE TE with a reduced-config model, submits a
batch of chat requests through the request-job-task path, and prints
completions + engine stats.

    PYTHONPATH=src python examples/quickstart.py [--arch qwen3-8b]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.engine import EngineConfig, FlowServe, Request, SamplingParams
from repro.engine.tokenizer import ByteTokenizer
from repro.models import get_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()

    print(f"[quickstart] loading {args.arch} (reduced config, CPU)")
    bundle = get_model(args.arch, smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    tok = ByteTokenizer()
    eng = FlowServe(bundle, params, EngineConfig(
        mode="colocated", n_pages=256, page_size=8, n_slots=8, max_len=256,
        max_batch_tokens=64, chunk_size=16, max_decode_batch=8))

    prompts = [
        "what is a serverless llm platform?",
        "explain prefill decode disaggregation",
        "how does a radix prefix cache work?",
        "what is a relational tensor cache?",
        "why pre-warm pods for fast scaling?",
        "what does npu-fork do?",
    ][: args.requests]
    sp = SamplingParams(temperature=0.8, top_p=0.95,
                        max_new_tokens=args.max_new, stop_on_eos=False)

    t0 = time.monotonic()
    ids = {}
    for p in prompts:
        rid = eng.add_request(Request(prompt_tokens=tok.encode(p), sampling=sp))
        ids[rid] = p
    comps = eng.run_to_completion()
    wall = time.monotonic() - t0

    total_tokens = sum(len(c.tokens) for c in comps)
    print(f"[quickstart] {len(comps)} completions, {total_tokens} tokens "
          f"in {wall:.2f}s ({total_tokens / wall:.1f} tok/s)")
    for c in comps:
        print(f"  - {ids[c.req_id][:36]!r:40s} ttft={c.ttft * 1e3:6.0f}ms "
              f"tpot={c.tpot * 1e3:6.1f}ms gen={tok.decode(c.tokens)[:32]!r}")
    print(f"[quickstart] prefix cache: {eng.prefix_cache_stats()}")
    print(f"[quickstart] engine steps: {eng.steps}, "
          f"scheduler critical-path: {eng.scheduler.sched_time * 1e3:.1f}ms total")


if __name__ == "__main__":
    main()
