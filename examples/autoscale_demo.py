"""Fast-scaling demo (§6): the AUTOSCALER reacts to a load spike using
pre-warmed pods/TEs + DRAM preload + NPU-fork, then scales back down.

    PYTHONPATH=src python examples/autoscale_demo.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import (AutoscalerConfig, ClusterManager, DRAMPageCache,
                        FastScaler, ModelAsset)
from repro.core.cluster import TaskExecutor
from repro.core.scaling import ModelLoader
from repro.engine.distflow import DistFlow


def main() -> None:
    asset = ModelAsset("llama3-8b", n_bytes=16e9, tp=1)
    dram = DRAMPageCache()
    scaler = FastScaler(dram, n_prewarm_pods=16, n_prewarm_tes=16)
    print(f"[autoscale] predictive preload of {asset.name} into DRAM page "
          f"cache: {dram.preload(asset)}")
    cm = ClusterManager(scaler, asset,
                        AutoscalerConfig(cooldown_s=0.0, max_tes=64))
    cm.register_te(TaskExecutor("te-0", "colocated"))

    # load spike: 0.3 -> 0.95 -> 0.98 -> cool-down
    t = 0.0
    for load in (0.3, 0.95, 0.98, 0.97, 0.4, 0.1, 0.1):
        t += 10.0
        delta = cm.autoscale(load=load, slo_violations=0.0, now=t)
        print(f"[autoscale] t={t:5.0f}s load={load:.2f} -> delta={delta:+d} "
              f"TEs={len(cm.tes)}")
    for ev in scaler.events:
        steps = " ".join(f"{k}={v:.2f}s" for k, v in ev.steps.items())
        print(f"  scale event {ev.te_id}: total={ev.total:.2f}s via {ev.path} ({steps})")

    # NPU-fork burst: clone weights from a running TE to 32 new TEs
    loader = ModelLoader(dram)
    src = DistFlow("running-te")
    targets = [DistFlow(f"new-te-{i}") for i in range(32)]
    src.link_cluster(targets)
    r = loader.npu_fork(asset, src, targets, link="ici")
    print(f"[autoscale] NPU-fork x32 over ICI: {r.seconds:.2f}s "
          f"({r.bytes_moved / 1e9:.0f} GB total)")
    r2 = loader.local_load(asset)
    print(f"[autoscale] vs DRAM-hit local load: {r2.seconds:.2f}s — "
          f"fork is {'faster' if r.seconds < r2.seconds else 'slower'} and "
          f"scales to N targets in one broadcast")


if __name__ == "__main__":
    main()
