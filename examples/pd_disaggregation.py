"""PD-disaggregated serving (§4.5): a prefill TE computes prompt KV and
ships it to a decode TE over DistFlow (by-req transfer), reproducing the
paper's task-level disaggregation end to end on CPU.

    PYTHONPATH=src python examples/pd_disaggregation.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.engine import EngineConfig, FlowServe, Request, SamplingParams
from repro.engine.tokenizer import ByteTokenizer
from repro.models import get_model


def main() -> None:
    bundle = get_model("h2o-danube-3-4b", smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)
    tok = ByteTokenizer()

    ecfg = lambda mode: EngineConfig(mode=mode, n_pages=128, page_size=8,
                                     max_batch_tokens=64, chunk_size=16,
                                     max_decode_batch=8)
    prefill_te = FlowServe(bundle, params, ecfg("prefill"), name="te-prefill-0")
    decode_te = FlowServe(bundle, params, ecfg("decode"), name="te-decode-0")
    prefill_te.distflow.link_cluster([decode_te.distflow])
    print("[pd] linked prefill TE <-> decode TE (DistFlow M:N channel)")

    sp = SamplingParams(temperature=0.0, max_new_tokens=24, stop_on_eos=False)
    prompts = [f"pd-disaggregation request number {i}: compute my kv cache"
               for i in range(4)]
    for p in prompts:
        prefill_te.add_request(Request(prompt_tokens=tok.encode(p), sampling=sp))

    comps, migrated = [], 0
    t0 = time.monotonic()
    while (prefill_te.has_work() or decode_te.has_work()
           or prefill_te._prefill_done_buffer):
        prefill_te.step()
        for rid in prefill_te.pop_migratable():
            # DistFlow v2: the KV run never leaves the devices — sharded
            # page runs stream over; the decode TE imports lazily at the
            # sequence's first decode step
            prefill_te.migrate_out(rid, decode_te)
            xfer = prefill_te.distflow.log[-1]
            migrated += 1
            print(f"[pd] migrated {rid}: {xfer.n_bytes / 1e3:.1f} KB KV over "
                  f"{xfer.backend}x{xfer.links} links "
                  f"(sim {xfer.sim_seconds * 1e6:.0f}us)")
        comps.extend(decode_te.step())
    print(f"[pd] {migrated} migrations, {len(comps)} completions "
          f"in {time.monotonic() - t0:.2f}s")
    for c in comps:
        print(f"  - {c.req_id}: {tok.decode(c.tokens)[:40]!r}")


if __name__ == "__main__":
    main()
