"""Fine-tune example — the FINE_TUNE request kind (§3): preprocess →
train → evaluate jobs on a ~100M-param dense model, with checkpointing and
crash-resume.

    PYTHONPATH=src python examples/finetune.py --steps 200
(CPU: ~100M params is slow; --small trains a ~10M variant quickly.)
"""
import argparse
import dataclasses
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data import DataConfig, PackedDataset
from repro.models import get_model
from repro.training import (CheckpointManager, OptimizerConfig, TrainConfig,
                            train)


def model_100m(small: bool) -> ModelConfig:
    if small:
        return ModelConfig(name="tiny-12m", family="dense", n_layers=4,
                           d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
                           d_ff=1024, vocab_size=8192, tie_embeddings=True)
    return ModelConfig(name="dense-100m", family="dense", n_layers=12,
                       d_model=640, n_heads=10, n_kv_heads=5, head_dim=64,
                       d_ff=2560, vocab_size=32000, tie_embeddings=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = model_100m(args.small)
    print(f"[finetune] model {cfg.name}: {cfg.param_count() / 1e6:.1f}M params")
    bundle = get_model(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0), jnp.float32)

    # preprocess job: tokenize + pack the corpus
    ds = PackedDataset(DataConfig(seq_len=args.seq_len, batch_size=args.batch,
                                  n_docs=4096))
    print(f"[finetune] preprocess job: {len(ds.windows)} packed windows")

    ckdir = tempfile.mkdtemp(prefix="deepserve_ft_")
    ck = CheckpointManager(ckdir, keep=2)
    tcfg = TrainConfig(steps=args.steps, log_every=20,
                       ckpt_every=max(args.steps // 4, 10),
                       opt=OptimizerConfig(lr=6e-4, warmup_steps=20,
                                           total_steps=args.steps))
    params, stats = train(bundle, params, ds.batches(epochs=1000), tcfg, ckpt=ck)
    print(f"[finetune] training job done: loss {stats['loss_first']:.3f} -> "
          f"{stats['loss_last']:.3f} in {stats['wall']:.1f}s; "
          f"checkpoints at {ckdir}: steps {ck.list_steps()}")

    # evaluation job: held-out perplexity
    ev = PackedDataset(DataConfig(seq_len=args.seq_len, batch_size=args.batch,
                                  n_docs=256, seed=99))
    tokens, targets, mask = next(ev.batches())
    loss = bundle.loss_fn(params, jnp.asarray(tokens), jnp.asarray(targets),
                          jnp.asarray(mask))
    print(f"[finetune] evaluation job: held-out loss {float(loss):.3f} "
          f"(ppl {float(jnp.exp(loss)):.1f})")


if __name__ == "__main__":
    main()
