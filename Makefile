# CI / developer entry points. XLA_FLAGS forces 8 simulated host devices so
# the SPMD tensor-parallel engine tests can build real 1xTP meshes on CPU
# (tests/conftest.py also sets this, so plain `pytest` behaves the same).

PYTEST   := PYTHONPATH=src python -m pytest
XLA_HOST := XLA_FLAGS="--xla_force_host_platform_device_count=8"

.PHONY: tier1 fast test-fleet test-faults bench-tp bench-pd bench-hotloop bench-prefill bench-serving bench-scaleout bench-faults bench help

tier1:  ## full tier-1 suite (ROADMAP.md verify command) on 8 simulated devices
	$(XLA_HOST) $(PYTEST) -x -q

fast:  ## fast subset: skips tests marked @pytest.mark.slow
	$(XLA_HOST) $(PYTEST) -x -q -m "not slow"

bench-tp:  ## tok/s for TP in {1,2,4} on simulated devices + sampler dispatches
	PYTHONPATH=src python benchmarks/bench_tp_engine.py

bench-pd:  ## PD KV-migration: host-gather v1 vs sharded device path at tp in {1,2,4}
	PYTHONPATH=src python benchmarks/bench_pd_migration.py

bench-hotloop:  ## decode hot loop: v1 host-driven vs v2 fused/multi-step at tp in {1,2,4}
	PYTHONPATH=src python benchmarks/bench_decode_hotloop.py

bench-prefill:  ## batched ragged prefill: legacy per-seq vs one-dispatch at tp in {1,2} (--json -> BENCH_prefill_batching.json)
	$(XLA_HOST) PYTHONPATH=src python -m benchmarks.run --only prefill_batching --json

FLEET_THREADS ?= 4

bench-serving:  ## live serving plane: Algorithm 1 vs RR + fleet-threads axis + scale-in (FLEET_THREADS=N)
	$(XLA_HOST) PYTHONPATH=src python benchmarks/bench_serving_plane.py \
		--fleet-threads $(FLEET_THREADS)

bench-scaleout:  ## cold-start ladder + fork-tree 1->N scale-out (--json -> BENCH_scale_out.json)
	$(XLA_HOST) PYTHONPATH=src python -m benchmarks.run --only scale_out --json

test-fleet:  ## just the multi-TE elastic-fleet lifecycle suite (slow lane)
	$(XLA_HOST) $(PYTEST) -x -q -m fleet

test-faults:  ## fault-injection + recovery suite (DESIGN.md §11)
	$(XLA_HOST) $(PYTEST) -x -q -m faults

bench-faults:  ## kill 1-of-N TEs mid-burst: recovery time, goodput dip, parity (--json -> BENCH_fault_recovery.json)
	$(XLA_HOST) PYTHONPATH=src python -m benchmarks.run --only fault_recovery --json

bench:  ## full paper-figure benchmark harness (XLA_HOST so tp_engine gets devices)
	$(XLA_HOST) PYTHONPATH=src python -m benchmarks.run

help:
	@grep -E '^[a-z0-9-]+:.*##' $(MAKEFILE_LIST) | sed 's/:.*## /\t/'
